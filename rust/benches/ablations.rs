//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Site ordering** (Morton vs random): Algorithm 1's premise is that
//!    an "appropriate ordering" concentrates covariance mass near the
//!    diagonal; random ordering should destroy the mixed-precision
//!    accuracy but *not* DP accuracy.
//! 2. **Tile size nb**: the paper notes nb must be tuned per machine
//!    (they use 960); sweep nb at fixed n.
//! 3. **Scheduler policy**: Fifo vs Lifo vs CriticalPath vs
//!    PrecisionFrontier on the same
//!    factorization (wall time; identical numerics is covered by tests).
//! 4. **Adaptive tolerance**: sweep `Variant::Adaptive`'s tolerance and
//!    report the realized dp/sp/bf16 tile census, the flop split, and the
//!    factor error against full DP.
//!
//! ```bash
//! cargo bench --bench ablations
//! ```

use mpcholesky::bench::{Stats, Table};
use mpcholesky::cholesky::{factorize_dense, solve_lower, Variant};
use mpcholesky::matern::{matern_matrix, Location, MaternParams, Metric};
use mpcholesky::prelude::*;
use mpcholesky::scheduler::{Scheduler, SchedulerConfig, SchedulingPolicy};
use mpcholesky::tile::DenseMatrix;

fn main() {
    ordering_ablation();
    nb_ablation();
    policy_ablation();
    tolerance_ablation();
}

/// 1. Morton vs random ordering: factor error of the mixed variant.
fn ordering_ablation() {
    println!("# ablation 1: site ordering (n = 1024, nb = 64, thick = 2)");
    let n = 1024;
    let nb = 64;
    let theta = MaternParams::new(1.0, 0.1, 0.5);
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let mut locs: Vec<Location> = (0..n)
        .map(|_| Location::new(rng.uniform_open(0.0, 1.0), rng.uniform_open(0.0, 1.0)))
        .collect();

    let mut table = Table::new(&["ordering", "||L_mp - L_dp||_max", "offband covariance mass"]);
    for (name, morton) in [("random", false), ("morton", true)] {
        let mut ordered = locs.clone();
        if morton {
            mpcholesky::datagen::morton_sort(&mut ordered);
        }
        let a = DenseMatrix::from_vec(
            n,
            matern_matrix(&ordered, &theta, Metric::Euclidean, 1e-8),
        )
        .unwrap();
        // off-band mass: fraction of |Sigma| outside diag_thick band
        let p = n / nb;
        let (mut inband, mut total) = (0.0f64, 0.0f64);
        for bj in 0..p {
            for bi in bj..p {
                let mut s = 0.0;
                for c in 0..nb {
                    for r in 0..nb {
                        s += a.get(bi * nb + r, bj * nb + c).abs();
                    }
                }
                total += s;
                if bi - bj < 2 {
                    inband += s;
                }
            }
        }
        let sched = Scheduler::with_workers(1);
        let dp = factorize_dense(&a, nb, Variant::FullDp, &NativeBackend, &sched)
            .unwrap()
            .to_dense(true);
        let mp = factorize_dense(
            &a,
            nb,
            Variant::MixedPrecision { diag_thick: 2 },
            &NativeBackend,
            &sched,
        )
        .unwrap()
        .to_dense(true);
        table.row(&[
            name.into(),
            format!("{:.3e}", mp.max_abs_diff(&dp)),
            format!("{:.1}% off-band", (1.0 - inband / total) * 100.0),
        ]);
    }
    table.print();
    let _ = &mut locs;
}

/// 2. nb sweep at fixed n: time of one DP factorization per tile size.
fn nb_ablation() {
    println!("\n# ablation 2: tile size (n = 2048, DP(100%), 1 worker)");
    let n = 2048;
    let theta = MaternParams::new(1.0, 0.1, 0.5);
    let field = SyntheticField::generate(&FieldConfig {
        n,
        theta,
        seed: 6,
        gen_nb: 128,
        ..Default::default()
    })
    .unwrap();
    let mut table = Table::new(&["nb", "p", "tasks", "median s"]);
    for nb in [64usize, 128, 256, 512] {
        let sched = Scheduler::with_workers(1);
        let times = mpcholesky::bench::time_reps(
            || {
                let mut tiles = mpcholesky::tile::TileMatrix::zeros(n, nb).unwrap();
                mpcholesky::cholesky::generate_and_factorize(
                    &mut tiles,
                    &field.locations,
                    theta,
                    Metric::Euclidean,
                    1e-8,
                    Variant::FullDp,
                    &NativeBackend,
                    &sched,
                )
                .unwrap();
                std::hint::black_box(&tiles);
            },
            1,
            3,
        );
        let p = n / nb;
        let plan = mpcholesky::cholesky::CholeskyPlan::build(p, nb, Variant::FullDp, true);
        table.row(&[
            format!("{nb}"),
            format!("{p}"),
            format!("{}", plan.graph.len()),
            format!("{:.4}", Stats::from(&times).median),
        ]);
    }
    table.print();
}

/// 3. Scheduling-policy wall time (single worker: measures queue overhead
/// only; multi-core hosts will show CriticalPath's pipelining advantage).
fn policy_ablation() {
    println!("\n# ablation 3: scheduler policy (n = 2048, nb = 128, MP thick = 2)");
    let n = 2048;
    let nb = 128;
    let theta = MaternParams::new(1.0, 0.1, 0.5);
    let field = SyntheticField::generate(&FieldConfig {
        n,
        theta,
        seed: 7,
        gen_nb: nb,
        ..Default::default()
    })
    .unwrap();
    let a = DenseMatrix::from_vec(
        n,
        matern_matrix(&field.locations, &theta, Metric::Euclidean, 1e-8),
    )
    .unwrap();
    let mut table = Table::new(&["policy", "median s", "utilization"]);
    for policy in [
        SchedulingPolicy::Fifo,
        SchedulingPolicy::Lifo,
        SchedulingPolicy::CriticalPath,
        SchedulingPolicy::PrecisionFrontier,
    ] {
        let workers = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        let sched = Scheduler::new(SchedulerConfig { num_workers: workers, policy, trace: true, ..Default::default() });
        let mut util = 0.0;
        let times = mpcholesky::bench::time_reps(
            || {
                let mut tiles = mpcholesky::tile::TileMatrix::from_dense(&a, nb).unwrap();
                let mut plan = mpcholesky::cholesky::CholeskyPlan::build(
                    n / nb,
                    nb,
                    Variant::MixedPrecision { diag_thick: 2 },
                    false,
                );
                tiles.demote_offband(|i, j| (i as isize - j as isize).unsigned_abs() < 2);
                let accesses: Vec<_> =
                    plan.graph.tasks().iter().map(|t| t.accesses.clone()).collect();
                let exec = mpcholesky::cholesky::TileExecutor::new(&tiles, &NativeBackend);
                let trace = sched
                    .run(&mut plan.graph, |idx, sc| exec.execute(sc, &accesses[idx]))
                    .unwrap();
                util = trace.utilization(workers);
                let u = solve_lower(&tiles, &field.values).unwrap();
                std::hint::black_box(u);
            },
            1,
            3,
        );
        table.row(&[
            format!("{policy:?}"),
            format!("{:.4}", Stats::from(&times).median),
            format!("{util:.2}"),
        ]);
    }
    table.print();
}

/// 4. Adaptive tolerance sweep: per-tolerance tile census, flop split,
/// and factor error vs full DP.
fn tolerance_ablation() {
    println!("\n# ablation 4: adaptive tolerance (n = 1024, nb = 128, Morton order)");
    let n = 1024;
    let nb = 128;
    let theta = MaternParams::new(1.0, 0.1, 0.5);
    let field = SyntheticField::generate(&FieldConfig {
        n,
        theta,
        seed: 8,
        gen_nb: nb,
        ..Default::default()
    })
    .unwrap();
    let a = DenseMatrix::from_vec(
        n,
        matern_matrix(&field.locations, &theta, Metric::Euclidean, 1e-8),
    )
    .unwrap();
    let workers = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let sched = Scheduler::with_workers(workers);
    let dp = factorize_dense(&a, nb, Variant::FullDp, &NativeBackend, &sched)
        .unwrap()
        .to_dense(true);
    let mut table =
        Table::new(&["tolerance", "realized split", "census + flops", "||L - L_dp||_max"]);
    for tol in [1e-12, 1e-8, 1e-4, 1e-2] {
        let mut tiles = mpcholesky::tile::TileMatrix::from_dense(&a, nb).unwrap();
        match mpcholesky::cholesky::factorize_tiles(
            &mut tiles,
            Variant::Adaptive { tolerance: tol },
            &NativeBackend,
            &sched,
        ) {
            Ok(plan) => {
                let l = tiles.to_dense(true);
                table.row(&[
                    format!("{tol:.0e}"),
                    plan.map.label(),
                    mpcholesky::bench::precision_summary(&plan),
                    format!("{:.3e}", l.max_abs_diff(&dp)),
                ]);
            }
            // very loose tolerances can lose positive definiteness —
            // that is a result, not a harness failure
            Err(e) => table.row(&[
                format!("{tol:.0e}"),
                "-".into(),
                format!("failed: {e}"),
                "-".into(),
            ]),
        }
    }
    table.print();
}

//! Fig. 5 reproduction: execution time + host<->device data movement on
//! CPU/GPU systems (K80, P100, V100), DP(100%) vs mixed variants.
//!
//! The paper's testbed GPUs are simulated per DESIGN.md SS3: the *real*
//! factorization task DAG for each variant is replayed under an analytic
//! device model (SP:DP throughput ratio, PCIe bandwidth, LRU device
//! memory).  Claims under test: mixed-precision cuts transfer volume by
//! ~40-60% and yields 1.7-2.2x modeled speedup.
//!
//! ```bash
//! cargo bench --bench fig5_gpu_datamove [-- n1,n2,...]
//! ```

use mpcholesky::bench::Table;
use mpcholesky::cholesky::{CholeskyPlan, Variant};
use mpcholesky::scheduler::datamove::{simulate, DeviceModel};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let ns: Vec<usize> = args
        .iter()
        .skip(1)
        .find(|a| !a.starts_with("--") && a.contains(|c: char| c.is_ascii_digit()))
        .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|| vec![16_384, 32_768, 49_152]);
    let nb = 512usize; // paper-scale GPU tile size

    for dev in [DeviceModel::k80(), DeviceModel::p100(), DeviceModel::v100()] {
        println!(
            "# Fig 5 ({}): dp={} GF/s sp={} GF/s pcie={} GB/s mem={} GiB",
            dev.name,
            dev.dp_gflops,
            dev.sp_gflops,
            dev.pcie_gbs,
            dev.gpu_mem_bytes >> 30
        );
        let mut table = Table::new(&[
            "n", "variant", "model time s", "moved GB", "transfer cut", "speedup vs DP",
        ]);
        for &n in &ns {
            let p = n / nb;
            let mut dp_time = 0.0f64;
            let mut dp_gb = 0.0f64;
            for dp_pct in [100.0, 10.0, 20.0, 40.0, 70.0, 90.0] {
                let variant = if dp_pct >= 100.0 {
                    Variant::FullDp
                } else {
                    Variant::MixedPrecision {
                        diag_thick: Variant::thick_for_dp_fraction(p, dp_pct),
                    }
                };
                let plan = CholeskyPlan::build(p, nb, variant, true);
                // transfers priced per tile at the realized storage map
                let rep = simulate(&plan.graph, &dev, nb, &plan.map);
                if variant == Variant::FullDp {
                    dp_time = rep.time_s;
                    dp_gb = rep.moved_gb();
                }
                table.row(&[
                    format!("{n}"),
                    variant.label(p),
                    format!("{:.3}", rep.time_s),
                    format!("{:.2}", rep.moved_gb()),
                    format!("{:.0}%", (1.0 - rep.moved_gb() / dp_gb) * 100.0),
                    format!("{:.2}x", dp_time / rep.time_s),
                ]);
            }
        }
        table.print();
    }
    println!("# paper reference: K80 1.74x / P100 2.18x / V100 1.82x; transfers cut 40-60%");
}

//! Fig. 6 reproduction: distributed-memory execution time and strong
//! scaling on a Shaheen-II-like Cray XC40 (64-512 nodes), DP(100%) vs
//! mixed variants.
//!
//! The cluster is simulated per DESIGN.md SS3: the real task DAG is
//! replayed under a 2D block-cyclic ownership + alpha-beta communication
//! model.  Claims under test: near-linear scaling, and a mixed-precision
//! speedup that *shrinks* with node count (1.61x @ 64 -> 1.27x @ 512)
//! as communication takes over.
//!
//! ```bash
//! cargo bench --bench fig6_distributed [-- n]
//! ```

use mpcholesky::bench::Table;
use mpcholesky::cholesky::{CholeskyPlan, Variant};
use mpcholesky::scheduler::distributed::{simulate, ClusterModel};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args
        .iter()
        .skip(1)
        .find_map(|a| a.parse().ok())
        .unwrap_or(131_072); // paper-scale problem for the model
    let nb = 1024usize; // distributed tile size
    let p = n / nb;

    println!("# Fig 6: Shaheen-II-like model, n = {n}, nb = {nb}, p = {p}");
    let mut table = Table::new(&[
        "nodes", "variant", "model time s", "comm GB", "speedup vs DP", "scaling vs 64",
    ]);
    let mut dp_at: Vec<(usize, f64)> = Vec::new();
    for nodes in [64usize, 128, 256, 512] {
        let cluster = ClusterModel::shaheen(nodes);
        let mut dp_time = 0.0f64;
        for dp_pct in [100.0, 10.0, 40.0, 90.0] {
            let variant = if dp_pct >= 100.0 {
                Variant::FullDp
            } else {
                Variant::MixedPrecision { diag_thick: Variant::thick_for_dp_fraction(p, dp_pct) }
            };
            let plan = CholeskyPlan::build(p, nb, variant, false);
            // transfers priced per tile at the realized storage map
            let rep = simulate(&plan.graph, &cluster, nb, &plan.map);
            if variant == Variant::FullDp {
                dp_time = rep.time_s;
                dp_at.push((nodes, rep.time_s));
            }
            let base64 = dp_at.first().map(|&(_, t)| t).unwrap_or(rep.time_s);
            table.row(&[
                format!("{nodes}"),
                variant.label(p),
                format!("{:.3}", rep.time_s),
                format!("{:.1}", rep.total_comm_bytes / 1e9),
                format!("{:.2}x", dp_time / rep.time_s),
                if variant == Variant::FullDp {
                    format!("{:.2}x", base64 / rep.time_s)
                } else {
                    String::new()
                },
            ]);
        }
    }
    table.print();
    println!("# paper reference: speedups 1.61x @64, 1.45x @128, 1.48x @256, 1.27x @512");
}

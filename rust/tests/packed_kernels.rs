//! Property tests for the packed micro-kernel BLAS layer: across tile
//! sizes (MR/NR-divisible sizes take the packed path, the odd size the
//! any-nb fallback), `gemm`/`syrk`/`trsm`/`potrf` must match their
//! `*_simple` dot-product oracles **bit-for-bit in f64** — the packed
//! kernels accumulate every element's k-sum in the oracle's
//! ascending-k order, so there is no tolerance to hide behind — and
//! within a small eps in f32 (same argument, with the looser bound
//! guarding against platform FMA contraction differences).

use mpcholesky::kernels::blas::{
    gemm, gemm_simple, potrf, potrf_simple, syrk, syrk_simple, trsm, trsm_simple,
};
use mpcholesky::rng::Xoshiro256pp;

/// 8/64/96/128 take the packed micro-kernel path; 37 is odd and
/// non-blockable, exercising the fallback dispatch.
const SIZES: [usize; 5] = [8, 64, 96, 128, 37];

fn rand_tile(nb: usize, seed: u64) -> Vec<f64> {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    (0..nb * nb).map(|_| r.standard_normal()).collect()
}

fn spd_tile(nb: usize, seed: u64) -> Vec<f64> {
    let b = rand_tile(nb, seed);
    let mut a = vec![0.0; nb * nb];
    for j in 0..nb {
        for i in 0..nb {
            let mut s = 0.0;
            for k in 0..nb {
                s += b[i + k * nb] * b[j + k * nb];
            }
            a[i + j * nb] = s + if i == j { nb as f64 } else { 0.0 };
        }
    }
    a
}

fn to_f32(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

fn assert_bitwise(got: &[f64], want: &[f64], what: &str, nb: usize) {
    for (k, (x, y)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what} nb={nb} [{k}]: {x} vs {y}");
    }
}

fn assert_close_f32(got: &[f32], want: &[f32], what: &str, nb: usize) {
    for (k, (x, y)) in got.iter().zip(want.iter()).enumerate() {
        let scale = y.abs().max(1.0);
        assert!(
            (x - y).abs() <= 8.0 * f32::EPSILON * scale * nb as f32,
            "{what} nb={nb} [{k}]: {x} vs {y}"
        );
    }
}

#[test]
fn packed_gemm_matches_oracle_bitwise_f64_and_eps_f32() {
    for &nb in &SIZES {
        let a = rand_tile(nb, 100 + nb as u64);
        let b = rand_tile(nb, 200 + nb as u64);
        let c0 = rand_tile(nb, 300 + nb as u64);

        let mut c_packed = c0.clone();
        let mut c_oracle = c0.clone();
        gemm(&mut c_packed, &a, &b, nb);
        gemm_simple(&mut c_oracle, &a, &b, nb);
        assert_bitwise(&c_packed, &c_oracle, "gemm", nb);

        let (a32, b32) = (to_f32(&a), to_f32(&b));
        let mut cp32 = to_f32(&c0);
        let mut co32 = to_f32(&c0);
        gemm(&mut cp32, &a32, &b32, nb);
        gemm_simple(&mut co32, &a32, &b32, nb);
        assert_close_f32(&cp32, &co32, "gemm/f32", nb);
    }
}

#[test]
fn packed_syrk_matches_oracle_bitwise_f64_and_eps_f32() {
    for &nb in &SIZES {
        let a = rand_tile(nb, 400 + nb as u64);
        let c0 = rand_tile(nb, 500 + nb as u64);

        let mut c_packed = c0.clone();
        let mut c_oracle = c0.clone();
        syrk(&mut c_packed, &a, nb);
        syrk_simple(&mut c_oracle, &a, nb);
        assert_bitwise(&c_packed, &c_oracle, "syrk", nb);
        // the strict upper triangle is untouched by either path
        for j in 1..nb {
            for i in 0..j {
                assert_eq!(c_packed[i + j * nb], c0[i + j * nb], "syrk upper nb={nb}");
            }
        }

        let a32 = to_f32(&a);
        let mut cp32 = to_f32(&c0);
        let mut co32 = to_f32(&c0);
        syrk(&mut cp32, &a32, nb);
        syrk_simple(&mut co32, &a32, nb);
        assert_close_f32(&cp32, &co32, "syrk/f32", nb);
    }
}

#[test]
fn packed_trsm_matches_oracle_bitwise_f64_and_eps_f32() {
    for &nb in &SIZES {
        let mut l = spd_tile(nb, 600 + nb as u64);
        potrf_simple(&mut l, nb, 0).unwrap();
        let b0 = rand_tile(nb, 700 + nb as u64);

        let mut b_packed = b0.clone();
        let mut b_oracle = b0.clone();
        trsm(&l, &mut b_packed, nb);
        trsm_simple(&l, &mut b_oracle, nb);
        assert_bitwise(&b_packed, &b_oracle, "trsm", nb);

        let l32 = to_f32(&l);
        let mut bp32 = to_f32(&b0);
        let mut bo32 = to_f32(&b0);
        trsm(&l32, &mut bp32, nb);
        trsm_simple(&l32, &mut bo32, nb);
        assert_close_f32(&bp32, &bo32, "trsm/f32", nb);
    }
}

#[test]
fn packed_potrf_matches_oracle_bitwise_f64_and_eps_f32() {
    for &nb in &SIZES {
        let a0 = spd_tile(nb, 800 + nb as u64);

        let mut l_packed = a0.clone();
        let mut l_oracle = a0.clone();
        potrf(&mut l_packed, nb, 0).unwrap();
        potrf_simple(&mut l_oracle, nb, 0).unwrap();
        assert_bitwise(&l_packed, &l_oracle, "potrf", nb);

        let mut lp32 = to_f32(&a0);
        let mut lo32 = to_f32(&a0);
        potrf(&mut lp32, nb, 0).unwrap();
        potrf_simple(&mut lo32, nb, 0).unwrap();
        assert_close_f32(&lp32, &lo32, "potrf/f32", nb);
    }
}

#[test]
fn packed_potrf_factor_reconstructs_spd_input() {
    // end-to-end sanity beyond oracle agreement: L L^T == A
    for &nb in &[64usize, 96] {
        let a0 = spd_tile(nb, 900 + nb as u64);
        let mut l = a0.clone();
        potrf(&mut l, nb, 0).unwrap();
        for j in 0..nb {
            for i in j..nb {
                let mut s = 0.0;
                for k in 0..nb {
                    s += l[i + k * nb] * l[j + k * nb];
                }
                let scale = a0[i + j * nb].abs().max(nb as f64);
                assert!(
                    (s - a0[i + j * nb]).abs() < 1e-9 * scale,
                    "nb={nb} ({i},{j}): {s} vs {}",
                    a0[i + j * nb]
                );
            }
        }
    }
}

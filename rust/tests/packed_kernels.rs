//! Property tests for the packed micro-kernel BLAS layer: across tile
//! sizes (MR/NR-divisible sizes take the packed path, the odd size the
//! any-nb fallback), `gemm`/`syrk`/`trsm`/`potrf` must match their
//! `*_simple` dot-product oracles **bit-for-bit in f64** — the packed
//! kernels accumulate every element's k-sum in the oracle's
//! ascending-k order, so there is no tolerance to hide behind — and
//! within a small eps in f32 (same argument, with the looser bound
//! guarding against platform FMA contraction differences).
//!
//! The SIMD dispatch layer gets the same treatment: every ISA tier the
//! hardware supports (`supported_isas()`) must produce **bit-identical**
//! f64 results to the `SimdIsa::Scalar` oracle — the vector f64
//! micro-kernels deliberately use separate mul+add (no FMA) and keep
//! the scalar kernel's ascending-k per-lane reduction order, so
//! `to_bits` equality is the contract, not a tolerance.  The f32 vector
//! kernels *do* fuse (FMA), so they carry the documented
//! `16 * eps * nb` accuracy bound instead.

use mpcholesky::kernels::blas::{
    active_isa, gemm, gemm_simple, gemm_with_isa, potrf, potrf_simple, potrf_with_isa,
    supported_isas, syrk, syrk_simple, syrk_with_isa, trsm, trsm_simple, trsm_with_isa, SimdIsa,
};
use mpcholesky::rng::Xoshiro256pp;

/// 8/64/96/128 take the packed micro-kernel path; 37 is odd and
/// non-blockable, exercising the fallback dispatch.
const SIZES: [usize; 5] = [8, 64, 96, 128, 37];

fn rand_tile(nb: usize, seed: u64) -> Vec<f64> {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    (0..nb * nb).map(|_| r.standard_normal()).collect()
}

fn spd_tile(nb: usize, seed: u64) -> Vec<f64> {
    let b = rand_tile(nb, seed);
    let mut a = vec![0.0; nb * nb];
    for j in 0..nb {
        for i in 0..nb {
            let mut s = 0.0;
            for k in 0..nb {
                s += b[i + k * nb] * b[j + k * nb];
            }
            a[i + j * nb] = s + if i == j { nb as f64 } else { 0.0 };
        }
    }
    a
}

fn to_f32(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

fn assert_bitwise(got: &[f64], want: &[f64], what: &str, nb: usize) {
    for (k, (x, y)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what} nb={nb} [{k}]: {x} vs {y}");
    }
}

fn assert_close_f32(got: &[f32], want: &[f32], what: &str, nb: usize) {
    for (k, (x, y)) in got.iter().zip(want.iter()).enumerate() {
        let scale = y.abs().max(1.0);
        // 16*eps*nb: the documented f32 SIMD accuracy bound — vector
        // f32 kernels use FMA (one rounding per mul+add instead of
        // two), so their reductions are *more* accurate but not
        // bit-identical to the scalar two-rounding order
        assert!(
            (x - y).abs() <= 16.0 * f32::EPSILON * scale * nb as f32,
            "{what} nb={nb} [{k}]: {x} vs {y}"
        );
    }
}

#[test]
fn packed_gemm_matches_oracle_bitwise_f64_and_eps_f32() {
    for &nb in &SIZES {
        let a = rand_tile(nb, 100 + nb as u64);
        let b = rand_tile(nb, 200 + nb as u64);
        let c0 = rand_tile(nb, 300 + nb as u64);

        let mut c_packed = c0.clone();
        let mut c_oracle = c0.clone();
        gemm(&mut c_packed, &a, &b, nb);
        gemm_simple(&mut c_oracle, &a, &b, nb);
        assert_bitwise(&c_packed, &c_oracle, "gemm", nb);

        let (a32, b32) = (to_f32(&a), to_f32(&b));
        let mut cp32 = to_f32(&c0);
        let mut co32 = to_f32(&c0);
        gemm(&mut cp32, &a32, &b32, nb);
        gemm_simple(&mut co32, &a32, &b32, nb);
        assert_close_f32(&cp32, &co32, "gemm/f32", nb);
    }
}

#[test]
fn packed_syrk_matches_oracle_bitwise_f64_and_eps_f32() {
    for &nb in &SIZES {
        let a = rand_tile(nb, 400 + nb as u64);
        let c0 = rand_tile(nb, 500 + nb as u64);

        let mut c_packed = c0.clone();
        let mut c_oracle = c0.clone();
        syrk(&mut c_packed, &a, nb);
        syrk_simple(&mut c_oracle, &a, nb);
        assert_bitwise(&c_packed, &c_oracle, "syrk", nb);
        // the strict upper triangle is untouched by either path
        for j in 1..nb {
            for i in 0..j {
                assert_eq!(c_packed[i + j * nb], c0[i + j * nb], "syrk upper nb={nb}");
            }
        }

        let a32 = to_f32(&a);
        let mut cp32 = to_f32(&c0);
        let mut co32 = to_f32(&c0);
        syrk(&mut cp32, &a32, nb);
        syrk_simple(&mut co32, &a32, nb);
        assert_close_f32(&cp32, &co32, "syrk/f32", nb);
    }
}

#[test]
fn packed_trsm_matches_oracle_bitwise_f64_and_eps_f32() {
    for &nb in &SIZES {
        let mut l = spd_tile(nb, 600 + nb as u64);
        potrf_simple(&mut l, nb, 0).unwrap();
        let b0 = rand_tile(nb, 700 + nb as u64);

        let mut b_packed = b0.clone();
        let mut b_oracle = b0.clone();
        trsm(&l, &mut b_packed, nb);
        trsm_simple(&l, &mut b_oracle, nb);
        assert_bitwise(&b_packed, &b_oracle, "trsm", nb);

        let l32 = to_f32(&l);
        let mut bp32 = to_f32(&b0);
        let mut bo32 = to_f32(&b0);
        trsm(&l32, &mut bp32, nb);
        trsm_simple(&l32, &mut bo32, nb);
        assert_close_f32(&bp32, &bo32, "trsm/f32", nb);
    }
}

#[test]
fn packed_potrf_matches_oracle_bitwise_f64_and_eps_f32() {
    for &nb in &SIZES {
        let a0 = spd_tile(nb, 800 + nb as u64);

        let mut l_packed = a0.clone();
        let mut l_oracle = a0.clone();
        potrf(&mut l_packed, nb, 0).unwrap();
        potrf_simple(&mut l_oracle, nb, 0).unwrap();
        assert_bitwise(&l_packed, &l_oracle, "potrf", nb);

        let mut lp32 = to_f32(&a0);
        let mut lo32 = to_f32(&a0);
        potrf(&mut lp32, nb, 0).unwrap();
        potrf_simple(&mut lo32, nb, 0).unwrap();
        assert_close_f32(&lp32, &lo32, "potrf/f32", nb);
    }
}

#[test]
fn active_isa_is_one_of_the_supported_tiers() {
    let supported = supported_isas();
    assert!(supported.contains(&SimdIsa::Scalar), "scalar tier always available");
    assert!(
        supported.contains(&active_isa()),
        "dispatch picked {:?}, not in supported set {supported:?}",
        active_isa()
    );
}

#[test]
fn simd_f64_kernels_bit_identical_to_scalar_oracle_across_isas() {
    // the tentpole contract: per ISA tier, per tile size (packed path
    // and odd fallback alike), f64 gemm/syrk/trsm/potrf must agree with
    // the scalar micro-kernel to the last bit
    for isa in supported_isas() {
        for &nb in &SIZES {
            let a = rand_tile(nb, 1000 + nb as u64);
            let b = rand_tile(nb, 1100 + nb as u64);
            let c0 = rand_tile(nb, 1200 + nb as u64);
            let what = format!("gemm[{isa:?}]");

            let mut c_isa = c0.clone();
            let mut c_ref = c0.clone();
            gemm_with_isa(&mut c_isa, &a, &b, nb, isa);
            gemm_with_isa(&mut c_ref, &a, &b, nb, SimdIsa::Scalar);
            assert_bitwise(&c_isa, &c_ref, &what, nb);

            let mut s_isa = c0.clone();
            let mut s_ref = c0.clone();
            syrk_with_isa(&mut s_isa, &a, nb, isa);
            syrk_with_isa(&mut s_ref, &a, nb, SimdIsa::Scalar);
            assert_bitwise(&s_isa, &s_ref, &format!("syrk[{isa:?}]"), nb);

            let mut l = spd_tile(nb, 1300 + nb as u64);
            potrf_simple(&mut l, nb, 0).unwrap();
            let mut b_isa = b.clone();
            let mut b_ref = b.clone();
            trsm_with_isa(&l, &mut b_isa, nb, isa);
            trsm_with_isa(&l, &mut b_ref, nb, SimdIsa::Scalar);
            assert_bitwise(&b_isa, &b_ref, &format!("trsm[{isa:?}]"), nb);

            let spd = spd_tile(nb, 1400 + nb as u64);
            let mut p_isa = spd.clone();
            let mut p_ref = spd.clone();
            potrf_with_isa(&mut p_isa, nb, 0, isa).unwrap();
            potrf_with_isa(&mut p_ref, nb, 0, SimdIsa::Scalar).unwrap();
            assert_bitwise(&p_isa, &p_ref, &format!("potrf[{isa:?}]"), nb);
        }
    }
}

#[test]
fn simd_f32_kernels_within_documented_bound_across_isas() {
    // f32 vector kernels fuse mul+add (FMA): not bit-identical to the
    // scalar order, but inside the documented 16*eps*nb envelope
    for isa in supported_isas() {
        for &nb in &SIZES {
            let a = to_f32(&rand_tile(nb, 1500 + nb as u64));
            let b = to_f32(&rand_tile(nb, 1600 + nb as u64));
            let c0 = to_f32(&rand_tile(nb, 1700 + nb as u64));

            let mut c_isa = c0.clone();
            let mut c_ref = c0.clone();
            gemm_with_isa(&mut c_isa, &a, &b, nb, isa);
            gemm_with_isa(&mut c_ref, &a, &b, nb, SimdIsa::Scalar);
            assert_close_f32(&c_isa, &c_ref, &format!("gemm/f32[{isa:?}]"), nb);

            let mut s_isa = c0.clone();
            let mut s_ref = c0;
            syrk_with_isa(&mut s_isa, &a, nb, isa);
            syrk_with_isa(&mut s_ref, &a, nb, SimdIsa::Scalar);
            assert_close_f32(&s_isa, &s_ref, &format!("syrk/f32[{isa:?}]"), nb);

            let mut l64 = spd_tile(nb, 1800 + nb as u64);
            potrf_simple(&mut l64, nb, 0).unwrap();
            let l = to_f32(&l64);
            let mut b_isa = b.clone();
            let mut b_ref = b;
            trsm_with_isa(&l, &mut b_isa, nb, isa);
            trsm_with_isa(&l, &mut b_ref, nb, SimdIsa::Scalar);
            assert_close_f32(&b_isa, &b_ref, &format!("trsm/f32[{isa:?}]"), nb);
        }
    }
}

#[test]
fn packed_potrf_factor_reconstructs_spd_input() {
    // end-to-end sanity beyond oracle agreement: L L^T == A
    for &nb in &[64usize, 96] {
        let a0 = spd_tile(nb, 900 + nb as u64);
        let mut l = a0.clone();
        potrf(&mut l, nb, 0).unwrap();
        for j in 0..nb {
            for i in j..nb {
                let mut s = 0.0;
                for k in 0..nb {
                    s += l[i + k * nb] * l[j + k * nb];
                }
                let scale = a0[i + j * nb].abs().max(nb as f64);
                assert!(
                    (s - a0[i + j * nb]).abs() < 1e-9 * scale,
                    "nb={nb} ({i},{j}): {s} vs {}",
                    a0[i + j * nb]
                );
            }
        }
    }
}

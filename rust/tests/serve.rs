//! Serving-layer soak suite: a deterministic mixed request stream
//! (kriging predicts at rotating thetas, periodic MLE fits and 2-fold
//! cross-validations) pushed through the admission controller across
//! worker counts, plus `PALLAS_INJECT=request:...` fault legs that
//! no-op unless CI arms them.
//!
//! Invariants pinned here:
//! * zero wedged or lost requests — every submitted copy is either
//!   answered exactly once or counted in `dropped`;
//! * the memory governor's budget is never breached;
//! * every shed is a typed `Error::Overloaded` with a retry hint;
//! * shed / deadline-miss / drop counts are deterministic (identical
//!   across reruns and worker counts);
//! * responses are bit-identical across worker counts, and cache-hit
//!   kriging answers are bit-identical to cold ones.

use std::sync::Arc;
use std::time::Duration;

use mpcholesky::fault::{env_plan, FaultPlan, ENV_VAR};
use mpcholesky::prelude::*;
use mpcholesky::serve::Request;

fn field(n: usize, seed: u64) -> SyntheticField {
    SyntheticField::generate(&FieldConfig {
        n,
        theta: MaternParams::new(1.0, 0.1, 0.5),
        seed,
        ..Default::default()
    })
    .unwrap()
}

/// Server shielded from ambient `PALLAS_INJECT` (the clean-leg tests
/// must not change behavior when CI arms a fault environment).
fn shielded(nb: usize, workers: usize) -> ServeConfig {
    ServeConfig {
        mle: MleConfig {
            nb,
            variant: Variant::MixedPrecision { diag_thick: 1 },
            num_workers: workers,
            optimizer: OptimizerConfig { max_evals: 30, ..Default::default() },
            ..Default::default()
        },
        faults: Some(Arc::new(FaultPlan::default())),
        ..Default::default()
    }
}

/// The deterministic mixed stream: predicts over shifted site blocks at
/// four rotating thetas (so the factorization cache gets both cold and
/// warm traffic), a 2-fold cross-validation every 101st request, an MLE
/// fit every 211th.
fn submit_stream(srv: &mut Server, f: &SyntheticField, count: usize) {
    let thetas = [
        MaternParams::new(1.0, 0.1, 0.5),
        MaternParams::new(1.2, 0.08, 0.6),
        MaternParams::new(0.8, 0.12, 0.7),
        MaternParams::new(1.5, 0.15, 0.5),
    ];
    let n = f.locations.len();
    let m = 64.min(n);
    for i in 0..count {
        if i % 211 == 17 {
            srv.submit(Request::Fit { locations: f.locations.clone(), z: f.values.clone() });
        } else if i % 101 == 13 {
            srv.submit(Request::Kfold {
                locations: f.locations.clone(),
                z: f.values.clone(),
                theta: thetas[i % thetas.len()],
                k: 2,
                seed: 7,
            });
        } else {
            let start = (i * 7) % (n - m + 1);
            srv.submit(Request::Predict {
                train: f.locations.clone(),
                z: f.values.clone(),
                theta: thetas[i % thetas.len()],
                sites: f.locations[start..start + m].to_vec(),
            });
        }
    }
}

/// Fold a response stream into an order-sensitive digest of its result
/// bits (predictions, fitted thetas, PMSEs) for cross-run comparison.
fn digest(responses: &[Response]) -> u64 {
    let mut d: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |v: u64| {
        d = d.rotate_left(7) ^ v.wrapping_mul(0x100_0000_01b3);
    };
    for r in responses {
        mix(r.id);
        match &r.result {
            Ok(Outcome::Predictions(p)) => p.iter().for_each(|x| mix(x.to_bits())),
            Ok(Outcome::Fitted { theta, loglik, .. }) => {
                mix(theta.variance.to_bits());
                mix(theta.range.to_bits());
                mix(theta.smoothness.to_bits());
                mix(loglik.to_bits());
            }
            Ok(Outcome::Pmse { mean_pmse, .. }) => mix(mean_pmse.to_bits()),
            Err(_) => mix(u64::MAX),
        }
    }
    d
}

#[test]
fn soak_1k_mixed_requests_across_worker_counts() {
    let f = field(128, 42);
    let mut digests = Vec::new();
    let mut control = Vec::new();
    for workers in [1usize, 4, 8] {
        let mut cfg = shielded(64, workers);
        cfg.queue_depth = 2048;
        cfg.budget_bytes = 64 << 20;
        let mut srv = Server::new(cfg);
        submit_stream(&mut srv, &f, 1050);
        let out = srv.drain();
        let s = srv.stats();
        // zero wedged or lost requests
        assert_eq!(s.submitted, 1050);
        assert_eq!(out.len() as u64 + s.dropped, s.submitted, "workers={workers}");
        assert_eq!(s.dropped, 0);
        for r in &out {
            assert!(r.result.is_ok(), "workers={workers} id={}: {:?}", r.id, r.result);
        }
        // governor held
        assert!(
            s.peak_resident_bytes <= s.budget_bytes,
            "workers={workers}: peak {} > budget {}",
            s.peak_resident_bytes,
            s.budget_bytes
        );
        // the cache took the bulk of the repeat traffic, and the packed
        // bf16 decode cache saw content-keyed hits
        assert!(s.cache_hits > 900, "workers={workers}: cache_hits={}", s.cache_hits);
        assert!(s.decode_cache_hits > 0, "workers={workers}");
        assert!(s.merged_runs >= 1, "workers={workers}");
        digests.push(digest(&out));
        control.push((s.shed, s.deadline_miss, s.dropped, s.failed, s.completed));
    }
    // deterministic control decisions AND bit-identical payloads across
    // worker counts
    assert_eq!(control[0], control[1]);
    assert_eq!(control[1], control[2]);
    assert_eq!(digests[0], digests[1], "payloads differ between 1 and 4 workers");
    assert_eq!(digests[1], digests[2], "payloads differ between 4 and 8 workers");
}

#[test]
fn shed_counts_deterministic_and_typed() {
    let f = field(128, 5);
    let run = || {
        let mut cfg = shielded(64, 4);
        cfg.queue_depth = 4;
        let mut srv = Server::new(cfg);
        for i in 0..20 {
            let start = (i * 3) % 64;
            srv.submit(Request::Predict {
                train: f.locations.clone(),
                z: f.values.clone(),
                theta: MaternParams::new(1.0, 0.1, 0.5),
                sites: f.locations[start..start + 8].to_vec(),
            });
        }
        let out = srv.drain();
        let s = srv.stats();
        assert_eq!(out.len(), 20);
        for r in &out {
            match &r.result {
                Ok(_) => {}
                Err(Error::Overloaded { retry_after_ms, reason }) => {
                    assert!(*retry_after_ms > 0);
                    assert_eq!(reason, "admission queue full");
                }
                Err(e) => panic!("unexpected error class: {e}"),
            }
        }
        (s.shed, s.completed)
    };
    let a = run();
    let b = run();
    assert_eq!(a, (16, 4), "queue bound 4 must shed exactly 16 of 20");
    assert_eq!(a, b, "shed counts must be deterministic across reruns");
}

#[test]
fn cache_hit_kriging_bit_identical_to_cold() {
    let f = field(128, 9);
    let mut srv = Server::new(shielded(64, 4));
    let req = Request::Predict {
        train: f.locations.clone(),
        z: f.values.clone(),
        theta: MaternParams::new(1.1, 0.09, 0.55),
        sites: f.locations[..32].to_vec(),
    };
    srv.submit(req.clone());
    let cold = srv.drain();
    srv.submit(req);
    let warm = srv.drain();
    assert!(!cold[0].cache_hit);
    assert!(warm[0].cache_hit);
    let (Ok(Outcome::Predictions(c)), Ok(Outcome::Predictions(w))) =
        (&cold[0].result, &warm[0].result)
    else {
        panic!("predicts failed: {:?} / {:?}", cold[0].result, warm[0].result);
    };
    assert_eq!(c.len(), w.len());
    for (a, b) in c.iter().zip(w.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "cache hit must be bit-identical");
    }
}

#[test]
fn tight_budget_backpressure_completes_everything() {
    let f = field(128, 31);
    let mut cfg = shielded(64, 4);
    let variant = Variant::MixedPrecision { diag_thick: 1 };
    let one = mpcholesky::serve::predicted_request_bytes(
        &Request::Predict {
            train: f.locations.clone(),
            z: f.values.clone(),
            theta: MaternParams::new(1.0, 0.1, 0.5),
            sites: f.locations[..64].to_vec(),
        },
        64,
        variant,
    );
    let fit = mpcholesky::serve::predicted_request_bytes(
        &Request::Fit { locations: f.locations.clone(), z: f.values.clone() },
        64,
        variant,
    );
    // headroom for the stream's largest request (the batched fit) plus
    // half a predict: a full admission batch can never fit at once
    cfg.budget_bytes = fit + one / 2;
    cfg.queue_depth = 256;
    let mut srv = Server::new(cfg);
    submit_stream(&mut srv, &f, 120);
    let out = srv.drain();
    let s = srv.stats();
    assert_eq!(out.len() as u64 + s.dropped, s.submitted);
    assert!(s.peak_resident_bytes <= s.budget_bytes);
    assert!(s.queued_rounds > 0, "the tight budget must have exercised backpressure");
    for r in &out {
        assert!(r.result.is_ok(), "id={}: {:?}", r.id, r.result);
    }
}

// ---------------------------------------------------------------------
// PALLAS_INJECT fault legs: no-ops unless CI arms the environment.
// ---------------------------------------------------------------------

fn env_spec() -> Option<String> {
    std::env::var(ENV_VAR).ok().filter(|s| !s.trim().is_empty())
}

/// Server riding the AMBIENT fault plan (cfg.faults = None resolves
/// `PALLAS_INJECT` at construction).
fn ambient_cfg(nb: usize) -> ServeConfig {
    ServeConfig {
        mle: MleConfig {
            nb,
            variant: Variant::MixedPrecision { diag_thick: 1 },
            num_workers: 4,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn predict_only_stream(srv: &mut Server, f: &SyntheticField, count: usize) {
    for i in 0..count {
        let start = (i * 7) % 64;
        srv.submit(Request::Predict {
            train: f.locations.clone(),
            z: f.values.clone(),
            theta: MaternParams::new(1.0, 0.1, 0.5),
            sites: f.locations[start..start + 16].to_vec(),
        });
    }
}

#[test]
fn env_leg_request_drop() {
    let Some(spec) = env_spec() else { return };
    if !spec.starts_with("request:drop") {
        return;
    }
    assert!(env_plan().is_some(), "spec {spec:?} failed to parse — fix the CI leg");
    let f = field(128, 3);
    let run = || {
        let mut srv = Server::new(ambient_cfg(64));
        predict_only_stream(&mut srv, &f, 200);
        let out = srv.drain();
        let s = srv.stats();
        // dropped copies are counted, never answered; everything else
        // is answered exactly once — the server never wedges
        assert_eq!(out.len() as u64 + s.dropped, s.submitted);
        assert!(s.dropped > 0, "rate>0 drop leg must drop something");
        for r in &out {
            assert!(r.result.is_ok(), "id={}: {:?}", r.id, r.result);
        }
        (s.dropped, out.len())
    };
    assert_eq!(run(), run(), "seeded drop decisions must be deterministic");
}

#[test]
fn env_leg_request_burst() {
    let Some(spec) = env_spec() else { return };
    if !spec.starts_with("request:burst") {
        return;
    }
    assert!(env_plan().is_some(), "spec {spec:?} failed to parse — fix the CI leg");
    let f = field(128, 3);
    let run = || {
        let mut cfg = ambient_cfg(64);
        cfg.queue_depth = 64;
        let mut srv = Server::new(cfg);
        predict_only_stream(&mut srv, &f, 100);
        let out = srv.drain();
        let s = srv.stats();
        assert!(s.submitted > 100, "burst leg must amplify submissions");
        assert_eq!(out.len() as u64 + s.dropped, s.submitted);
        for r in &out {
            match &r.result {
                Ok(_) => {}
                Err(Error::Overloaded { retry_after_ms, .. }) => assert!(*retry_after_ms > 0),
                Err(e) => panic!("burst leg: unexpected error class {e}"),
            }
        }
        (s.submitted, s.shed, out.len())
    };
    assert_eq!(run(), run(), "seeded burst decisions must be deterministic");
}

#[test]
fn env_leg_request_delay_deadline_miss() {
    let Some(spec) = env_spec() else { return };
    if !spec.starts_with("request:delay") {
        return;
    }
    assert!(env_plan().is_some(), "spec {spec:?} failed to parse — fix the CI leg");
    let f = field(128, 3);
    let run = || {
        let mut cfg = ambient_cfg(64);
        // generous real-time deadline: only the injected virtual delay
        // (CI arms ms >> this budget) can force a miss, deterministically
        cfg.deadline = Some(Duration::from_secs(60));
        let mut srv = Server::new(cfg);
        predict_only_stream(&mut srv, &f, 50);
        let out = srv.drain();
        let s = srv.stats();
        assert_eq!(out.len() as u64 + s.dropped, s.submitted);
        assert!(s.deadline_miss > 0, "delay leg must miss deadlines");
        for r in &out {
            match &r.result {
                Ok(_) => {}
                Err(Error::DeadlineExceeded { budget_ms, .. }) => assert_eq!(*budget_ms, 60_000),
                Err(e) => panic!("delay leg: unexpected error class {e}"),
            }
        }
        (s.deadline_miss, out.len())
    };
    assert_eq!(run(), run(), "seeded delay decisions must be deterministic");
}

//! End-to-end integration: generate -> fit -> predict across variants.
//! These tests assert the paper's *accuracy* claims at laptop scale:
//!
//! * mixed-precision likelihood/estimates/PMSE track full DP closely;
//! * DST loses positive definiteness or accuracy on correlated data;
//! * the headline pipeline runs start-to-finish on every variant.

use mpcholesky::prelude::*;

fn field(n: usize, range: f64, seed: u64) -> SyntheticField {
    SyntheticField::generate(&FieldConfig {
        n,
        theta: MaternParams::new(1.0, range, 0.5),
        seed,
        ..Default::default()
    })
    .unwrap()
}

#[test]
fn likelihood_agreement_across_variants() {
    let f = field(512, 0.1, 1);
    let theta = f.theta;
    let mk = |variant| MleConfig { nb: 64, variant, ..Default::default() };
    let ll = |variant| {
        MleProblem::new(&f.locations, &f.values, mk(variant))
            .unwrap()
            .loglik(&theta)
            .unwrap()
    };
    let dp = ll(Variant::FullDp);
    for thick in [1, 2, 4] {
        let mp = ll(Variant::MixedPrecision { diag_thick: thick });
        let gap = (dp - mp).abs() / dp.abs();
        assert!(gap < 1e-3, "thick={thick}: relative loglik gap {gap}");
    }
}

#[test]
fn dst_breaks_on_strong_correlation_with_thin_band() {
    // zeroing off-band blocks of a strongly correlated covariance loses
    // positive definiteness — the paper's DST failure mode
    let f = field(512, 0.3, 2);
    let cfg = MleConfig { nb: 64, variant: Variant::Dst { diag_thick: 1 }, ..Default::default() };
    let prob = MleProblem::new(&f.locations, &f.values, cfg).unwrap();
    let r = prob.loglik(&f.theta);
    match r {
        Err(Error::NotPositiveDefinite { .. }) => {} // expected
        Ok(ll) => {
            // if it happens to stay PD, the likelihood must be visibly
            // degraded relative to DP
            let dp = MleProblem::new(
                &f.locations,
                &f.values,
                MleConfig { nb: 64, variant: Variant::FullDp, ..Default::default() },
            )
            .unwrap()
            .loglik(&f.theta)
            .unwrap();
            assert!(
                (dp - ll).abs() / dp.abs() > 1e-3,
                "DST should not match DP on strong correlation: {dp} vs {ll}"
            );
        }
        Err(e) => panic!("unexpected error {e}"),
    }
}

#[test]
fn dst_works_on_weak_correlation() {
    let f = field(512, 0.03, 3);
    let cfg = MleConfig { nb: 64, variant: Variant::Dst { diag_thick: 4 }, ..Default::default() };
    let prob = MleProblem::new(&f.locations, &f.values, cfg).unwrap();
    assert!(prob.loglik(&f.theta).is_ok());
}

#[test]
fn full_pipeline_all_variants() {
    let f = field(512, 0.1, 4);
    for variant in [
        Variant::FullDp,
        Variant::MixedPrecision { diag_thick: 2 },
        Variant::MixedPrecision { diag_thick: 4 },
    ] {
        let cfg = MleConfig {
            nb: 64,
            variant,
            start: Some([0.8, 0.08, 0.6]),
            optimizer: OptimizerConfig { max_evals: 40, ftol: 1e-2, ..Default::default() },
            ..Default::default()
        };
        let prob = MleProblem::new(&f.locations, &f.values, cfg.clone()).unwrap();
        let fit = prob.fit().unwrap();
        assert!(fit.loglik.is_finite());
        // prediction at the fitted parameters must beat the variance
        // baseline on correlated data
        let rep = kfold_pmse(&f.locations, &f.values, fit.theta, 4, &cfg, 5).unwrap();
        assert!(rep.mean_pmse < 1.0, "{variant:?}: PMSE {}", rep.mean_pmse);
    }
}

#[test]
fn estimates_agree_between_dp_and_mixed() {
    let f = field(512, 0.1, 6);
    let fit = |variant| {
        let cfg = MleConfig {
            nb: 64,
            variant,
            start: Some([0.8, 0.08, 0.6]),
            optimizer: OptimizerConfig { max_evals: 80, ftol: 1e-4, ..Default::default() },
            ..Default::default()
        };
        MleProblem::new(&f.locations, &f.values, cfg).unwrap().fit().unwrap()
    };
    let dp = fit(Variant::FullDp);
    let mp = fit(Variant::MixedPrecision { diag_thick: 2 });
    // the two optimizers see nearly identical surfaces; estimates must be
    // close in relative terms (the paper's Fig. 7/Table I claim)
    let rel = |a: f64, b: f64| (a - b).abs() / a.abs().max(1e-9);
    let close = |a: f64, b: f64| rel(a, b) < 0.15;
    assert!(close(dp.theta.variance, mp.theta.variance), "{:?} vs {:?}", dp.theta, mp.theta);
    assert!(close(dp.theta.range, mp.theta.range), "{:?} vs {:?}", dp.theta, mp.theta);
    assert!(close(dp.theta.smoothness, mp.theta.smoothness), "{:?} vs {:?}", dp.theta, mp.theta);
}

#[test]
fn mixed_saves_flops_proportionally() {
    // the plan's SP flop share at DP(10%)-SP(90%) must be large enough to
    // explain the paper's 1.6-1.8x speedups given 2x SP throughput
    use mpcholesky::cholesky::CholeskyPlan;
    let p = 20;
    let t = Variant::thick_for_dp_fraction(p, 10.0);
    let plan = CholeskyPlan::build(p, 128, Variant::MixedPrecision { diag_thick: t }, false);
    let sp_frac = plan.sp_flop_fraction();
    assert!(sp_frac > 0.6, "sp flop share {sp_frac}");
    // ideal speedup with 2x SP rate: 1 / (dp + sp/2)
    let ideal = 1.0 / ((1.0 - sp_frac) + sp_frac / 2.0);
    assert!(ideal > 1.4, "ideal speedup {ideal}");
}

//! Acceptance tests for precision-native tile storage.
//!
//! The old scheme kept a canonical f64 buffer per tile plus an f32
//! shadow for demoted tiles, so "mixed precision" *increased* the
//! resident footprint to ~1.5x DP(100%).  With native storage the
//! footprint must satisfy the paper's inequality instead:
//!
//! * mixed-precision resident bytes strictly below full-DP bytes;
//! * post-run resident bytes exactly equal to the precision map's
//!   native footprint (all conversion scratch freed by the plan's
//!   `DropScratch` tasks);
//! * factorization backward error at the storage format's level,
//!   across tile sizes exercising both the register-blocked
//!   (`nb % 8 == 0`) and fallback kernel paths.

use mpcholesky::matern::matern_matrix;
use mpcholesky::prelude::*;
use mpcholesky::tile::DenseMatrix;

fn matern_dense_with_range(n: usize, seed: u64, range: f64) -> DenseMatrix {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut locs: Vec<Location> = (0..n)
        .map(|_| Location::new(r.uniform_open(0.0, 1.0), r.uniform_open(0.0, 1.0)))
        .collect();
    mpcholesky::datagen::morton_sort(&mut locs);
    DenseMatrix::from_vec(
        n,
        matern_matrix(&locs, &MaternParams::new(1.0, range, 0.5), Metric::Euclidean, 1e-8),
    )
    .unwrap()
}

fn matern_dense(n: usize, seed: u64) -> DenseMatrix {
    matern_dense_with_range(n, seed, 0.1)
}

/// `||L L^T - A||_max` over the lower triangle.
fn backward_error(tiles: &TileMatrix, a: &DenseMatrix) -> f64 {
    let l = tiles.to_dense(true);
    let llt = l.matmul_nt(&l);
    let n = a.n();
    let mut err = 0.0f64;
    for j in 0..n {
        for i in j..n {
            err = err.max((llt.get(i, j) - a.get(i, j)).abs());
        }
    }
    err
}

#[test]
fn resident_bytes_and_backward_error_across_tile_sizes() {
    // all three tile sizes divide by MR = 8 / NR = 4: the register-
    // blocked potrf/trsm/gemm/syrk paths carry the whole factorization
    // (DP backward error itself is covered by the cholesky unit tests
    // and the fallback test below — here DP provides the byte baseline)
    for &(n, nb) in &[(768usize, 96usize), (1024, 128), (960, 160)] {
        let a = matern_dense(n, 11 + nb as u64);
        let sched = Scheduler::with_workers(4);

        let mut t_dp = TileMatrix::from_dense(&a, nb).unwrap();
        factorize_tiles(&mut t_dp, Variant::FullDp, &NativeBackend, &sched).unwrap();
        assert_eq!(t_dp.resident_bytes(), t_dp.full_dp_bytes(), "n={n} nb={nb}");

        let mut t_mp = TileMatrix::from_dense(&a, nb).unwrap();
        let plan_mp = factorize_tiles(
            &mut t_mp,
            Variant::MixedPrecision { diag_thick: 2 },
            &NativeBackend,
            &sched,
        )
        .unwrap();
        assert!(
            t_mp.resident_bytes() < t_dp.resident_bytes(),
            "n={n} nb={nb}: mixed resident {} !< full-DP {}",
            t_mp.resident_bytes(),
            t_dp.resident_bytes()
        );
        assert_eq!(
            t_mp.resident_bytes(),
            plan_mp.map.storage_bytes(nb),
            "n={n} nb={nb}: conversion scratch leaked past the run"
        );
        let e_mp = backward_error(&t_mp, &a);
        assert!(e_mp < 5e-4, "n={n} nb={nb}: mixed backward error {e_mp}");
    }
}

#[test]
fn acceptance_mixed_and_adaptive_resident_bytes_n1024_nb128() {
    // the issue's reference point: n = 1024, nb = 128 — band *and*
    // adaptive assignments must strictly undercut the DP footprint
    let (n, nb) = (1024, 128);
    let p = n / nb;
    let a = matern_dense(n, 42);
    let sched = Scheduler::with_workers(4);

    let mut t_dp = TileMatrix::from_dense(&a, nb).unwrap();
    factorize_tiles(&mut t_dp, Variant::FullDp, &NativeBackend, &sched).unwrap();
    let dp_bytes = t_dp.resident_bytes();
    assert_eq!(dp_bytes, t_dp.full_dp_bytes());

    let mut t_mp = TileMatrix::from_dense(&a, nb).unwrap();
    let plan_mp = factorize_tiles(
        &mut t_mp,
        Variant::MixedPrecision { diag_thick: 2 },
        &NativeBackend,
        &sched,
    )
    .unwrap();
    assert!(
        t_mp.resident_bytes() < dp_bytes,
        "band: {} !< {dp_bytes}",
        t_mp.resident_bytes()
    );
    assert_eq!(t_mp.resident_bytes(), plan_mp.map.storage_bytes(nb));

    let mut t_ad = TileMatrix::from_dense(&a, nb).unwrap();
    let plan_ad = factorize_tiles(
        &mut t_ad,
        Variant::Adaptive { tolerance: 1e-8 },
        &NativeBackend,
        &sched,
    )
    .unwrap();
    let census = plan_ad.census();
    assert!(
        census.dp < p * (p + 1) / 2,
        "adaptive demoted nothing: {census:?} ({})",
        plan_ad.map.label()
    );
    assert!(
        t_ad.resident_bytes() < dp_bytes,
        "adaptive: {} !< {dp_bytes}",
        t_ad.resident_bytes()
    );
    assert_eq!(t_ad.resident_bytes(), plan_ad.map.storage_bytes(nb));
    // the realized storage matches the plan's assignment tile-for-tile
    assert_eq!(t_ad.storage_map(), plan_ad.map);
}

#[test]
fn fallback_kernel_path_keeps_accounting_and_accuracy() {
    // nb = 100 is not divisible by the microkernel MR = 8, so every
    // codelet runs its simple fallback form — accounting and accuracy
    // must be path-independent
    let (n, nb) = (600, 100);
    let a = matern_dense(n, 7);
    let sched = Scheduler::with_workers(2);

    let mut t_dp = TileMatrix::from_dense(&a, nb).unwrap();
    factorize_tiles(&mut t_dp, Variant::FullDp, &NativeBackend, &sched).unwrap();
    let e_dp = backward_error(&t_dp, &a);
    assert!(e_dp < 1e-9, "fallback DP backward error {e_dp}");

    let mut t_mp = TileMatrix::from_dense(&a, nb).unwrap();
    let plan_mp = factorize_tiles(
        &mut t_mp,
        Variant::MixedPrecision { diag_thick: 2 },
        &NativeBackend,
        &sched,
    )
    .unwrap();
    assert!(t_mp.resident_bytes() < t_dp.resident_bytes());
    assert_eq!(t_mp.resident_bytes(), plan_mp.map.storage_bytes(nb));
    let e_mp = backward_error(&t_mp, &a);
    assert!(e_mp < 5e-4, "fallback mixed backward error {e_mp}");
}

#[test]
fn three_precision_resident_counts_packed_bf16() {
    // p = 5 with dp_thick = 2, sp_thick = 4: 9 f64 tiles, 5 f32 tiles
    // and exactly one packed-bf16 tile (4,0) at 2 bytes/element
    let (n, nb) = (640, 128);
    // weaker correlation keeps the bf16-rounded far tile safely PD
    let a = matern_dense_with_range(n, 5, 0.05);
    let sched = Scheduler::with_workers(2);
    let mut tiles = TileMatrix::from_dense(&a, nb).unwrap();
    let plan = factorize_tiles(
        &mut tiles,
        Variant::ThreePrecision { dp_thick: 2, sp_thick: 4 },
        &NativeBackend,
        &sched,
    )
    .unwrap();
    let nn = nb * nb;
    assert_eq!(tiles.hp_bytes(), nn * 2, "one packed bf16 tile");
    assert_eq!(tiles.sp_bytes(), 5 * nn * 4);
    assert_eq!(tiles.dp_bytes(), 9 * nn * 8);
    assert_eq!(tiles.resident_bytes(), plan.map.storage_bytes(nb));
    assert!(tiles.resident_bytes() < tiles.full_dp_bytes());
    let err = backward_error(&tiles, &a);
    assert!(err < 0.1, "three-precision backward error {err}");
}

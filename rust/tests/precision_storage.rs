//! Acceptance tests for precision-native tile storage.
//!
//! The old scheme kept a canonical f64 buffer per tile plus an f32
//! shadow for demoted tiles, so "mixed precision" *increased* the
//! resident footprint to ~1.5x DP(100%).  With native storage the
//! footprint must satisfy the paper's inequality instead:
//!
//! * mixed-precision resident bytes strictly below full-DP bytes;
//! * post-run resident bytes exactly equal to the precision map's
//!   native footprint (all conversion scratch freed by the plan's
//!   `DropScratch` tasks);
//! * factorization backward error at the storage format's level,
//!   across tile sizes exercising both the register-blocked
//!   (`nb % 8 == 0`) and fallback kernel paths.

use mpcholesky::matern::matern_matrix;
use mpcholesky::prelude::*;
use mpcholesky::tile::f16::{f16_bits_to_f32, f32_to_f16_bits};
use mpcholesky::tile::{DenseMatrix, Precision, PrecisionMap};

fn matern_dense_with_range(n: usize, seed: u64, range: f64) -> DenseMatrix {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut locs: Vec<Location> = (0..n)
        .map(|_| Location::new(r.uniform_open(0.0, 1.0), r.uniform_open(0.0, 1.0)))
        .collect();
    mpcholesky::datagen::morton_sort(&mut locs);
    DenseMatrix::from_vec(
        n,
        matern_matrix(&locs, &MaternParams::new(1.0, range, 0.5), Metric::Euclidean, 1e-8),
    )
    .unwrap()
}

fn matern_dense(n: usize, seed: u64) -> DenseMatrix {
    matern_dense_with_range(n, seed, 0.1)
}

/// `||L L^T - A||_max` over the lower triangle.
fn backward_error(tiles: &TileMatrix, a: &DenseMatrix) -> f64 {
    let l = tiles.to_dense(true);
    let llt = l.matmul_nt(&l);
    let n = a.n();
    let mut err = 0.0f64;
    for j in 0..n {
        for i in j..n {
            err = err.max((llt.get(i, j) - a.get(i, j)).abs());
        }
    }
    err
}

#[test]
fn resident_bytes_and_backward_error_across_tile_sizes() {
    // all three tile sizes divide by MR = 8 / NR = 4: the register-
    // blocked potrf/trsm/gemm/syrk paths carry the whole factorization
    // (DP backward error itself is covered by the cholesky unit tests
    // and the fallback test below — here DP provides the byte baseline)
    for &(n, nb) in &[(768usize, 96usize), (1024, 128), (960, 160)] {
        let a = matern_dense(n, 11 + nb as u64);
        let sched = Scheduler::with_workers(4);

        let mut t_dp = TileMatrix::from_dense(&a, nb).unwrap();
        factorize_tiles(&mut t_dp, Variant::FullDp, &NativeBackend, &sched).unwrap();
        assert_eq!(t_dp.resident_bytes(), t_dp.full_dp_bytes(), "n={n} nb={nb}");

        let mut t_mp = TileMatrix::from_dense(&a, nb).unwrap();
        let plan_mp = factorize_tiles(
            &mut t_mp,
            Variant::MixedPrecision { diag_thick: 2 },
            &NativeBackend,
            &sched,
        )
        .unwrap();
        assert!(
            t_mp.resident_bytes() < t_dp.resident_bytes(),
            "n={n} nb={nb}: mixed resident {} !< full-DP {}",
            t_mp.resident_bytes(),
            t_dp.resident_bytes()
        );
        assert_eq!(
            t_mp.resident_bytes(),
            plan_mp.map.storage_bytes(nb),
            "n={n} nb={nb}: conversion scratch leaked past the run"
        );
        let e_mp = backward_error(&t_mp, &a);
        assert!(e_mp < 5e-4, "n={n} nb={nb}: mixed backward error {e_mp}");
    }
}

#[test]
fn acceptance_mixed_and_adaptive_resident_bytes_n1024_nb128() {
    // the issue's reference point: n = 1024, nb = 128 — band *and*
    // adaptive assignments must strictly undercut the DP footprint
    let (n, nb) = (1024, 128);
    let p = n / nb;
    let a = matern_dense(n, 42);
    let sched = Scheduler::with_workers(4);

    let mut t_dp = TileMatrix::from_dense(&a, nb).unwrap();
    factorize_tiles(&mut t_dp, Variant::FullDp, &NativeBackend, &sched).unwrap();
    let dp_bytes = t_dp.resident_bytes();
    assert_eq!(dp_bytes, t_dp.full_dp_bytes());

    let mut t_mp = TileMatrix::from_dense(&a, nb).unwrap();
    let plan_mp = factorize_tiles(
        &mut t_mp,
        Variant::MixedPrecision { diag_thick: 2 },
        &NativeBackend,
        &sched,
    )
    .unwrap();
    assert!(
        t_mp.resident_bytes() < dp_bytes,
        "band: {} !< {dp_bytes}",
        t_mp.resident_bytes()
    );
    assert_eq!(t_mp.resident_bytes(), plan_mp.map.storage_bytes(nb));

    let mut t_ad = TileMatrix::from_dense(&a, nb).unwrap();
    let plan_ad = factorize_tiles(
        &mut t_ad,
        Variant::Adaptive { tolerance: 1e-8 },
        &NativeBackend,
        &sched,
    )
    .unwrap();
    let census = plan_ad.census();
    assert!(
        census.dp < p * (p + 1) / 2,
        "adaptive demoted nothing: {census:?} ({})",
        plan_ad.map.label()
    );
    assert!(
        t_ad.resident_bytes() < dp_bytes,
        "adaptive: {} !< {dp_bytes}",
        t_ad.resident_bytes()
    );
    assert_eq!(t_ad.resident_bytes(), plan_ad.map.storage_bytes(nb));
    // the realized storage matches the plan's assignment tile-for-tile
    assert_eq!(t_ad.storage_map(), plan_ad.map);
}

#[test]
fn fallback_kernel_path_keeps_accounting_and_accuracy() {
    // nb = 100 is not divisible by the microkernel MR = 8, so every
    // codelet runs its simple fallback form — accounting and accuracy
    // must be path-independent
    let (n, nb) = (600, 100);
    let a = matern_dense(n, 7);
    let sched = Scheduler::with_workers(2);

    let mut t_dp = TileMatrix::from_dense(&a, nb).unwrap();
    factorize_tiles(&mut t_dp, Variant::FullDp, &NativeBackend, &sched).unwrap();
    let e_dp = backward_error(&t_dp, &a);
    assert!(e_dp < 1e-9, "fallback DP backward error {e_dp}");

    let mut t_mp = TileMatrix::from_dense(&a, nb).unwrap();
    let plan_mp = factorize_tiles(
        &mut t_mp,
        Variant::MixedPrecision { diag_thick: 2 },
        &NativeBackend,
        &sched,
    )
    .unwrap();
    assert!(t_mp.resident_bytes() < t_dp.resident_bytes());
    assert_eq!(t_mp.resident_bytes(), plan_mp.map.storage_bytes(nb));
    let e_mp = backward_error(&t_mp, &a);
    assert!(e_mp < 5e-4, "fallback mixed backward error {e_mp}");
}

#[test]
fn three_precision_resident_counts_packed_bf16() {
    // p = 5 with dp_thick = 2, sp_thick = 4: 9 f64 tiles, 5 f32 tiles
    // and exactly one packed-bf16 tile (4,0) at 2 bytes/element
    let (n, nb) = (640, 128);
    // weaker correlation keeps the bf16-rounded far tile safely PD
    let a = matern_dense_with_range(n, 5, 0.05);
    let sched = Scheduler::with_workers(2);
    let mut tiles = TileMatrix::from_dense(&a, nb).unwrap();
    let plan = factorize_tiles(
        &mut tiles,
        Variant::ThreePrecision { dp_thick: 2, sp_thick: 4 },
        &NativeBackend,
        &sched,
    )
    .unwrap();
    let nn = nb * nb;
    assert_eq!(tiles.hp_bytes(), nn * 2, "one packed bf16 tile");
    assert_eq!(tiles.sp_bytes(), 5 * nn * 4);
    assert_eq!(tiles.dp_bytes(), 9 * nn * 8);
    assert_eq!(tiles.resident_bytes(), plan.map.storage_bytes(nb));
    assert!(tiles.resident_bytes() < tiles.full_dp_bytes());
    let err = backward_error(&tiles, &a);
    assert!(err < 0.1, "three-precision backward error {err}");
}

#[test]
fn four_precision_resident_counts_packed_f16() {
    // p = 5 with dp_thick = 2, sp_thick = 3, f16_thick = 4: 9 f64
    // tiles, 3 f32 tiles, 2 packed-f16 tiles (3,0) and (4,1), and one
    // packed-bf16 tile (4,0) — both 2-byte rings accounted separately
    let (n, nb) = (640, 128);
    let a = matern_dense_with_range(n, 6, 0.05);
    let sched = Scheduler::with_workers(2);
    let mut tiles = TileMatrix::from_dense(&a, nb).unwrap();
    let plan = factorize_tiles(
        &mut tiles,
        Variant::FourPrecision { dp_thick: 2, sp_thick: 3, f16_thick: 4 },
        &NativeBackend,
        &sched,
    )
    .unwrap();
    let census = plan.census();
    assert_eq!((census.dp, census.sp, census.f16, census.hp), (9, 3, 2, 1), "{census:?}");
    let nn = nb * nb;
    assert_eq!(tiles.f16_bytes(), 2 * nn * 2, "two packed f16 tiles");
    assert_eq!(tiles.hp_bytes(), nn * 2, "one packed bf16 tile");
    assert_eq!(tiles.sp_bytes(), 3 * nn * 4);
    assert_eq!(tiles.dp_bytes(), 9 * nn * 8);
    assert_eq!(tiles.resident_bytes(), plan.map.storage_bytes(nb));
    assert!(tiles.resident_bytes() < tiles.full_dp_bytes());
    // f16's three extra mantissa bits: the four-tier factor must stay
    // at least as accurate as the all-bf16-tail three-tier band above
    let err = backward_error(&tiles, &a);
    assert!(err < 0.1, "four-precision backward error {err}");
}

#[test]
fn precision_ladder_bytes_and_eps_are_monotone() {
    // the four-tier ladder: bytes non-increasing, storage roundoff
    // strictly increasing, f64 > f32 > f16 > bf16
    let ladder =
        [Precision::F64, Precision::F32, Precision::F16, Precision::Bf16];
    for w in ladder.windows(2) {
        assert!(w[0].bytes() >= w[1].bytes(), "{w:?} bytes out of order");
        assert!(w[0].eps() < w[1].eps(), "{w:?} eps out of order");
    }
    assert_eq!(Precision::F16.bytes(), 2);
    assert_eq!(Precision::Bf16.bytes(), 2);
}

#[test]
fn f16_is_exactly_embedded_in_f32() {
    // every non-NaN f16 bit pattern — all normals, all subnormals, both
    // zeros, both infinities — expands to f32 and re-encodes to the
    // identical bits: the nesting f16 ⊂ f32 (⊂ f64) is exact, so
    // promote/demote chains through the ladder lose nothing on values
    // already representable downstairs
    for bits in 0u16..=u16::MAX {
        let x = f16_bits_to_f32(bits);
        if x.is_nan() {
            continue;
        }
        assert_eq!(
            f32_to_f16_bits(x),
            bits,
            "bits {bits:#06x} -> {x} failed to round-trip"
        );
        // and the f64 leg of the nesting: through f64 and back to f32
        // is the identity on f16-representable values
        assert_eq!((x as f64) as f32, x, "bits {bits:#06x}");
    }
}

#[test]
fn adaptive_rule_walks_the_four_tier_ladder() {
    // pick_adaptive at fixed cal = 1: loosening the tolerance walks
    // F64 -> F32 -> F16 -> Bf16, each tier claimed at the documented
    // eps threshold (f32 2^-23, f16 2^-10, bf16 2^-7)
    assert_eq!(Precision::pick_adaptive(1.0, 1e-8), Precision::F64);
    assert_eq!(Precision::pick_adaptive(1.0, 1e-6), Precision::F32);
    assert_eq!(Precision::pick_adaptive(1.0, 1e-3), Precision::F16);
    assert_eq!(Precision::pick_adaptive(1.0, 1e-2), Precision::Bf16);
    // tier is monotone in tolerance: a looser budget never buys a more
    // expensive format
    let mut tol = 1e-10;
    let mut prev = Precision::pick_adaptive(1.0, tol);
    while tol < 1.0 {
        tol *= 1.5;
        let now = Precision::pick_adaptive(1.0, tol);
        assert!(now.eps() >= prev.eps(), "tier regressed at tol {tol}");
        prev = now;
    }
    assert_eq!(prev, Precision::Bf16, "sweep must end at bf16");
}

#[test]
fn adaptive_map_reaches_f16_and_never_demotes_diagonals() {
    // a factor-2 tolerance sweep is denser than the factor-8 window
    // (tol*128 <= cal < tol*1024) in which a tile takes f16, so some
    // tolerance must land at least one off-diagonal tile on the f16
    // tier; diagonals stay F64 at every tolerance (potrf pivots)
    let (n, nb) = (640, 128);
    let a = matern_dense_with_range(n, 9, 0.05);
    let tiles = TileMatrix::from_dense(&a, nb).unwrap();
    let p = tiles.p();
    let mut saw_f16 = false;
    let mut prev_bytes = usize::MAX;
    let mut tol = 1e-7;
    while tol < 0.2 {
        let map = PrecisionMap::adaptive(&tiles, tol);
        for k in 0..p {
            assert_eq!(map.get(k, k), Precision::F64, "diagonal ({k},{k}) demoted at tol {tol}");
        }
        let bytes = map.storage_bytes(nb);
        assert!(bytes <= prev_bytes, "footprint grew when tolerance loosened to {tol}");
        prev_bytes = bytes;
        saw_f16 |= map.census().f16 > 0;
        tol *= 2.0;
    }
    assert!(saw_f16, "no tolerance in the sweep reached the f16 tier");
}

//! Whole-iteration pipeline acceptance tests:
//!
//! * multi-RHS tiled `SolveFwd`/`SolveBwd` and the `LogDetPartial` chain
//!   are **bit-identical** (`to_bits`) to the serial oracles in full DP
//!   across nb in {8, 64, 96} x r in {1, 4};
//! * the fused Adaptive pipeline runs generation, per-panel-column map
//!   resolution, factorization and the epilogue as ONE `Scheduler::run`
//!   — no whole-matrix barrier — and still factors correctly;
//! * k-fold PMSE rides one batched multi-RHS graph and is deterministic:
//!   same seed => bit-identical PMSE under 1/4/8 workers and all four
//!   scheduling policies, and identical to the serial fit+predict path;
//! * the MLE trace reports the pipeline's solve/log-det task counts and
//!   modeled transfer bytes for the full iteration.

use mpcholesky::cholesky::{
    factorize_dense, log_determinant, run_pipeline, solve_lower, solve_lower_transposed, KernelCall,
    PanelResolver, PipelineBuffers, PipelineOptions, PipelinePlan, Variant,
};
use mpcholesky::kernels::NativeBackend;
use mpcholesky::matern::{matern_matrix, Location, MaternParams, Metric};
use mpcholesky::mle::{MleConfig, MleProblem};
use mpcholesky::predict::{kfold_pmse, pmse, KrigingModel};
use mpcholesky::rng::Xoshiro256pp;
use mpcholesky::scheduler::{Scheduler, SchedulingPolicy};
use mpcholesky::tile::{DenseMatrix, TileMatrix};

fn matern_locs(n: usize, seed: u64) -> Vec<Location> {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut locs: Vec<Location> = (0..n)
        .map(|_| Location::new(r.uniform_open(0.0, 1.0), r.uniform_open(0.0, 1.0)))
        .collect();
    locs.sort_by(|a, b| (a.x + a.y).partial_cmp(&(b.x + b.y)).unwrap());
    locs
}

fn spd_dense(n: usize, seed: u64) -> DenseMatrix {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut b = DenseMatrix::zeros(n);
    for j in 0..n {
        for i in 0..n {
            b.set(i, j, r.standard_normal());
        }
    }
    let mut a = b.matmul_nt(&b);
    for i in 0..n {
        a.set(i, i, a.get(i, i) + n as f64);
    }
    a
}

/// Multi-RHS tiled solves + log-det chain vs the serial oracles, full
/// DP, `to_bits` equality over the required nb x r sweep.
#[test]
fn multi_rhs_solves_bit_identical_to_serial_oracles() {
    for nb in [8usize, 64, 96] {
        let p = 4;
        let n = p * nb;
        let a = spd_dense(n, 1000 + nb as u64);
        let sched = Scheduler::with_workers(4);
        let tiles = factorize_dense(&a, nb, Variant::FullDp, &NativeBackend, &sched).unwrap();
        for r in [1usize, 4] {
            let opts = PipelineOptions {
                rhs_cols: r,
                backward: true,
                logdet: true,
                ..Default::default()
            };
            let mut plan = PipelinePlan::build_epilogue(p, nb, Variant::FullDp, opts);
            // the solve stage is one graph regardless of r: task count
            // scales with tiles, each task sweeps all r columns
            assert_eq!(plan.counts.solve_fwd, p + p * (p - 1) / 2, "nb={nb} r={r}");
            let mut bufs = PipelineBuffers::new(p, nb, r, 0);
            let mut rng = Xoshiro256pp::seed_from_u64(2000 + (nb + r) as u64);
            let cols: Vec<Vec<f64>> = (0..r)
                .map(|_| (0..n).map(|_| rng.standard_normal()).collect())
                .collect();
            for (c, v) in cols.iter().enumerate() {
                bufs.load_column(c, v);
            }
            run_pipeline(&mut plan, &tiles, &bufs, None, None, None, &NativeBackend, &sched)
                .unwrap();
            for (c, v) in cols.iter().enumerate() {
                let y = solve_lower(&tiles, v).unwrap();
                let x = solve_lower_transposed(&tiles, &y).unwrap();
                let got = bufs.column(c);
                assert_eq!(got.len(), x.len());
                for (d, (g, w)) in got.iter().zip(x.iter()).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "nb={nb} r={r} col={c} row={d}: {g} vs {w}"
                    );
                }
            }
            assert_eq!(
                bufs.logdet().to_bits(),
                log_determinant(&tiles).to_bits(),
                "nb={nb} r={r}: log-det chain diverges from the serial oracle"
            );
        }
    }
}

/// The fused Adaptive pipeline: generation tasks live in the SAME graph
/// as the factorization (the acceptance property — no whole-matrix
/// barrier), one `Scheduler::run` produces a valid factor, the realized
/// map keeps the diagonal DP, and zero tolerance reproduces the full-DP
/// factor bit-for-bit.
#[test]
fn adaptive_pipeline_is_one_graph_and_factors_correctly() {
    let n = 160;
    let nb = 32;
    let p = n / nb;
    let locs = matern_locs(n, 41);
    let theta = MaternParams::new(1.0, 0.1, 0.5);
    let a = DenseMatrix::from_vec(n, matern_matrix(&locs, &theta, Metric::Euclidean, 1e-8))
        .unwrap();
    let sched = Scheduler::with_workers(4);

    let run_adaptive = |tolerance: f64| -> (TileMatrix, PipelinePlan) {
        let opts = PipelineOptions { rhs_cols: 0, logdet: false, ..Default::default() };
        let mut plan = PipelinePlan::build_adaptive(p, nb, tolerance, opts);
        // acceptance: the fused Adaptive plan contains Generate tasks
        assert!(
            plan.graph
                .tasks()
                .iter()
                .any(|t| matches!(t.payload.call, KernelCall::Generate { .. })),
            "fused adaptive plan lost its generation stage"
        );
        let tiles = TileMatrix::zeros(n, nb).unwrap();
        let bufs = PipelineBuffers::new(p, nb, 0, 0);
        let resolver = PanelResolver::new(p, tolerance);
        let gen = mpcholesky::cholesky::GenContext {
            locations: &locs,
            theta,
            metric: Metric::Euclidean,
            nugget: 1e-8,
        };
        run_pipeline(
            &mut plan,
            &tiles,
            &bufs,
            Some(&resolver),
            None,
            Some(gen),
            &NativeBackend,
            &sched,
        )
        .unwrap();
        (tiles, plan)
    };

    // tolerance 0: nothing demotes; bit-identical to the full-DP factor
    let (t0, plan0) = run_adaptive(0.0);
    let dp = factorize_dense(&a, nb, Variant::FullDp, &NativeBackend, &sched).unwrap();
    assert_eq!(t0.to_dense(true).max_abs_diff(&dp.to_dense(true)), 0.0);
    let map0 = plan0.realized_map(&t0);
    assert_eq!(map0.census().dp, p * (p + 1) / 2, "tolerance 0 demoted a tile");

    // a real tolerance: tiles demote, the diagonal stays DP, and the
    // factor still reconstructs the covariance to mixed-precision level
    let (t1, plan1) = run_adaptive(1e-6);
    let map1 = plan1.realized_map(&t1);
    assert!(map1.diagonal_is_dp(), "per-column resolution demoted a diagonal tile");
    let l = t1.to_dense(true);
    let llt = l.matmul_nt(&l);
    let mut err = 0.0f64;
    for j in 0..n {
        for i in j..n {
            err = err.max((llt.get(i, j) - a.get(i, j)).abs());
        }
    }
    assert!(err < 5e-5, "adaptive pipeline reconstruction err {err}");
}

/// Per-column (prefix-norm) resolution is conservative relative to the
/// whole-matrix rule: it never stores a tile in LOWER precision than
/// the two-phase adaptive map would.
#[test]
fn per_column_resolution_never_demotes_below_whole_matrix_rule() {
    let n = 192;
    let nb = 32;
    let p = n / nb;
    let locs = matern_locs(n, 43);
    let theta = MaternParams::new(1.0, 0.08, 0.5);
    let tol = 1e-6;
    let sched = Scheduler::with_workers(3);

    // whole-matrix rule (two-phase oracle path)
    let mut gen_tiles = TileMatrix::zeros(n, nb).unwrap();
    mpcholesky::cholesky::generate_covariance(
        &mut gen_tiles,
        &locs,
        theta,
        Metric::Euclidean,
        1e-8,
        &NativeBackend,
        &sched,
    )
    .unwrap();
    let full_map = Variant::Adaptive { tolerance: tol }
        .precision_map(p, Some(&gen_tiles))
        .unwrap();

    // per-column rule (one-graph pipeline)
    let opts = PipelineOptions { rhs_cols: 0, logdet: false, ..Default::default() };
    let mut plan = PipelinePlan::build_adaptive(p, nb, tol, opts);
    let tiles = TileMatrix::zeros(n, nb).unwrap();
    let bufs = PipelineBuffers::new(p, nb, 0, 0);
    let resolver = PanelResolver::new(p, tol);
    let gen = mpcholesky::cholesky::GenContext {
        locations: &locs,
        theta,
        metric: Metric::Euclidean,
        nugget: 1e-8,
    };
    run_pipeline(&mut plan, &tiles, &bufs, Some(&resolver), None, Some(gen), &NativeBackend, &sched)
        .unwrap();
    let col_map = plan.realized_map(&tiles);

    // Precision derives Ord with Bf16 < F32 < F64: "conservative" means
    // the per-column assignment is >= the whole-matrix one everywhere
    for i in 0..p {
        for j in 0..=i {
            assert!(
                col_map.get(i, j) >= full_map.get(i, j),
                "tile ({i},{j}): per-column {:?} below whole-matrix {:?}",
                col_map.get(i, j),
                full_map.get(i, j)
            );
        }
    }
    // and it is not vacuous: something still demotes under the prefix rule
    assert!(col_map.census().dp < p * (p + 1) / 2, "prefix rule demoted nothing");
}

/// k-fold PMSE determinism: one batched multi-RHS graph, same seed =>
/// bit-identical fold PMSEs under 1/4/8 workers and all four policies —
/// and identical to the serial fit+predict path for the same fold split.
#[test]
fn kfold_pmse_deterministic_across_workers_and_policies() {
    use mpcholesky::datagen::{FieldConfig, SyntheticField};
    let f = SyntheticField::generate(&FieldConfig {
        n: 256,
        theta: MaternParams::new(1.0, 0.1, 0.5),
        seed: 5,
        ..Default::default()
    })
    .unwrap();
    let k = 4;
    let seed = 9;
    let mut reference: Option<Vec<u64>> = None;
    for policy in [
        SchedulingPolicy::Fifo,
        SchedulingPolicy::Lifo,
        SchedulingPolicy::CriticalPath,
        SchedulingPolicy::PrecisionFrontier,
    ] {
        for workers in [1usize, 4, 8] {
            let cfg = MleConfig {
                nb: 64,
                variant: Variant::MixedPrecision { diag_thick: 2 },
                num_workers: workers,
                policy,
                ..Default::default()
            };
            let rep = kfold_pmse(&f.locations, &f.values, f.theta, k, &cfg, seed).unwrap();
            assert_eq!(rep.fold_pmse.len(), k);
            let bits: Vec<u64> = rep.fold_pmse.iter().map(|v| v.to_bits()).collect();
            if let Some(want) = &reference {
                assert_eq!(&bits, want, "{policy:?}/{workers}w: PMSE diverges");
            } else {
                reference = Some(bits);
            }
        }
    }

    // cross-check fold 0 against the serial fit+predict path (same
    // shuffle => same membership): the batched graph must reproduce it
    let n = f.locations.len();
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    rng.shuffle(&mut idx);
    let fold_len = n / k;
    let mut mask = vec![false; n];
    for &t in &idx[0..fold_len] {
        mask[t] = true;
    }
    let (mut tr_locs, mut tr_z, mut te_locs, mut te_z) =
        (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    for i in 0..n {
        if mask[i] {
            te_locs.push(f.locations[i]);
            te_z.push(f.values[i]);
        } else {
            tr_locs.push(f.locations[i]);
            tr_z.push(f.values[i]);
        }
    }
    let cfg = MleConfig {
        nb: 64,
        variant: Variant::MixedPrecision { diag_thick: 2 },
        ..Default::default()
    };
    let model = KrigingModel::fit(&tr_locs, &tr_z, f.theta, &cfg).unwrap();
    let serial = pmse(&model.predict(&te_locs), &te_z);
    let rep = kfold_pmse(&f.locations, &f.values, f.theta, k, &cfg, seed).unwrap();
    assert_eq!(
        rep.fold_pmse[0].to_bits(),
        serial.to_bits(),
        "batched fold 0 diverges from serial fit+predict"
    );
}

/// Adaptive k-fold also runs through the batched graph (dynamic
/// per-fold resolution) and stays deterministic.
#[test]
fn adaptive_kfold_is_deterministic() {
    use mpcholesky::datagen::{FieldConfig, SyntheticField};
    let f = SyntheticField::generate(&FieldConfig {
        n: 256,
        theta: MaternParams::new(1.0, 0.1, 0.5),
        seed: 6,
        ..Default::default()
    })
    .unwrap();
    let mk = |workers: usize| MleConfig {
        nb: 64,
        variant: Variant::Adaptive { tolerance: 1e-6 },
        num_workers: workers,
        ..Default::default()
    };
    let a = kfold_pmse(&f.locations, &f.values, f.theta, 4, &mk(1), 3).unwrap();
    let b = kfold_pmse(&f.locations, &f.values, f.theta, 4, &mk(8), 3).unwrap();
    for (x, y) in a.fold_pmse.iter().zip(b.fold_pmse.iter()) {
        assert_eq!(x.to_bits(), y.to_bits(), "adaptive k-fold diverges across widths");
    }
    // and the predictor is actually predictive
    assert!(a.mean_pmse.is_finite() && a.mean_pmse > 0.0);
}

/// The MLE trace reports the whole iteration: solve + log-det task
/// counts and modeled transfer bytes for the full pipeline graph, for
/// every variant — and the adaptive likelihood (per-column rule) stays
/// within the established relative tolerance of full DP.
#[test]
fn mle_trace_reports_full_iteration_pipeline() {
    use mpcholesky::datagen::{FieldConfig, SyntheticField};
    let f = SyntheticField::generate(&FieldConfig {
        n: 256,
        theta: MaternParams::new(1.0, 0.1, 0.5),
        seed: 8,
        gen_nb: 64,
        ..Default::default()
    })
    .unwrap();
    let theta = f.theta;
    let p = 256 / 64;
    let mut dp_ll = None;
    for variant in [
        Variant::FullDp,
        Variant::MixedPrecision { diag_thick: 2 },
        Variant::ThreePrecision { dp_thick: 2, sp_thick: 4 },
        Variant::Adaptive { tolerance: 1e-6 },
    ] {
        let cfg = MleConfig { nb: 64, variant, ..Default::default() };
        let prob = MleProblem::new(&f.locations, &f.values, cfg).unwrap();
        let ll = prob.loglik(&theta).unwrap();
        let trace = prob.trace();
        assert_eq!(trace.iterations.len(), 1);
        let it = &trace.iterations[0];
        // forward solve tasks: p diagonal + p(p-1)/2 updates; log-det
        // chain: one per diagonal tile; all inside ONE pipeline graph
        assert_eq!(it.solve_tasks, p + p * (p - 1) / 2, "{variant:?}");
        assert_eq!(it.logdet_tasks, p, "{variant:?}");
        assert_eq!(it.crosscov_tasks, 0, "{variant:?}");
        assert!(
            it.pipeline_tasks > it.solve_tasks + it.logdet_tasks,
            "{variant:?}: pipeline graph missing its factor stage"
        );
        assert!(it.modeled_transfer_bytes > 0.0, "{variant:?}");
        match variant {
            Variant::FullDp => dp_ll = Some(ll),
            Variant::Adaptive { .. } => {
                let dp = dp_ll.expect("FullDp ran first");
                assert!(
                    (dp - ll).abs() < 1e-3 * dp.abs().max(1.0),
                    "adaptive pipeline loglik {ll} vs DP {dp}"
                );
            }
            _ => {}
        }
    }
}

/// Reduced-precision factors promote identically through the pipeline
/// solves and the serial oracles: mixed-precision pipelines are
/// bit-identical to the oracle epilogue too (the promotion is exact in
/// both paths).
#[test]
fn mixed_precision_pipeline_solves_match_oracles_bitwise() {
    let nb = 32;
    let p = 5;
    let n = p * nb;
    let locs = matern_locs(n, 77);
    let theta = MaternParams::new(1.0, 0.1, 0.5);
    let a = DenseMatrix::from_vec(n, matern_matrix(&locs, &theta, Metric::Euclidean, 1e-8))
        .unwrap();
    let sched = Scheduler::with_workers(4);
    let variant = Variant::ThreePrecision { dp_thick: 1, sp_thick: 3 };
    let tiles = factorize_dense(&a, nb, variant, &NativeBackend, &sched).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(78);
    let b: Vec<f64> = (0..n).map(|_| rng.standard_normal()).collect();

    let opts = PipelineOptions { rhs_cols: 1, backward: true, logdet: true, ..Default::default() };
    let mut plan = PipelinePlan::build_epilogue(p, nb, variant, opts);
    let mut bufs = PipelineBuffers::new(p, nb, 1, 0);
    bufs.load_column(0, &b);
    run_pipeline(&mut plan, &tiles, &bufs, None, None, None, &NativeBackend, &sched).unwrap();

    let y = solve_lower(&tiles, &b).unwrap();
    let x = solve_lower_transposed(&tiles, &y).unwrap();
    for (g, w) in bufs.column(0).iter().zip(x.iter()) {
        assert_eq!(g.to_bits(), w.to_bits());
    }
    assert_eq!(bufs.logdet().to_bits(), log_determinant(&tiles).to_bits());
    // sanity: the factor really holds reduced tiles
    let map = tiles.storage_map();
    assert!(map.census().sp + map.census().hp > 0);
}

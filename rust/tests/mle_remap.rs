//! MLE Adaptive-remap regression: as the optimizer moves theta, the
//! recomputed norm-based precision map must never demote a diagonal
//! tile (the potrf pivots), the remap stride must be honored, and the
//! adaptive fit's log-likelihood must match the full-DP variant within
//! the relative tolerance the adaptive acceptance path already uses
//! (1e-3, as in `mixed_loglik_close_to_dp_loglik`).

use mpcholesky::prelude::*;

fn field() -> SyntheticField {
    SyntheticField::generate(&FieldConfig {
        n: 256,
        theta: MaternParams::new(1.0, 0.1, 0.5),
        seed: 9,
        gen_nb: 64,
        ..Default::default()
    })
    .unwrap()
}

fn cfg(variant: Variant, remap_every: usize) -> MleConfig {
    MleConfig {
        nb: 64,
        variant,
        remap_every,
        optimizer: OptimizerConfig { max_evals: 60, ftol: 1e-4, ..Default::default() },
        lower: [0.05, 0.01, 0.25],
        upper: [10.0, 1.0, 1.5],
        start: Some([0.5, 0.05, 0.8]),
        ..Default::default()
    }
}

#[test]
fn adaptive_remap_never_demotes_diagonal_and_matches_dp_loglik() {
    let f = field();
    let adaptive = Variant::Adaptive { tolerance: 1e-6 };

    let dp_prob = MleProblem::new(&f.locations, &f.values, cfg(Variant::FullDp, 1)).unwrap();
    let ad_prob = MleProblem::new(&f.locations, &f.values, cfg(adaptive, 3)).unwrap();

    let ad_fit = ad_prob.fit().unwrap();
    let trace = &ad_fit.trace;
    assert!(!trace.iterations.is_empty());

    // 1. the recomputed map never demotes a diagonal tile, at any theta
    //    the optimizer visits
    for (i, it) in trace.iterations.iter().enumerate() {
        assert!(it.diagonal_dp, "iteration {i} demoted a diagonal tile");
        assert_eq!(it.census.total(), 4 * 5 / 2, "p = 4 triangle");
    }

    // 2. remap stride 3 is honored over successful evaluations: maps are
    //    recomputed exactly at evals 0, 3, 6, ... and reused in between
    //    (a reused map cannot churn)
    for (i, it) in trace.iterations.iter().enumerate() {
        assert_eq!(it.remapped, i % 3 == 0, "eval {i} remap cadence");
        if !it.remapped {
            assert_eq!(it.map_churn, 0, "eval {i}: reused map reported churn");
        }
    }

    // 3. per-eval modeled transfer volume is populated on the realized map
    assert!(trace.iterations.iter().all(|it| it.modeled_transfer_bytes > 0.0));

    // 4. the adaptive fit's likelihood matches full DP at the same theta
    //    within the established 1e-3 relative tolerance
    let dp_at_ad_theta = dp_prob.loglik(&ad_fit.theta).unwrap();
    assert!(
        (dp_at_ad_theta - ad_fit.loglik).abs() < 1e-3 * dp_at_ad_theta.abs().max(1.0),
        "adaptive loglik {} vs DP {} at theta-hat",
        ad_fit.loglik,
        dp_at_ad_theta
    );

    // 5. and the two fits land on likelihoods of the same height
    let dp_fit = dp_prob.fit().unwrap();
    assert!(
        (dp_fit.loglik - ad_fit.loglik).abs() < 1e-2 * dp_fit.loglik.abs().max(1.0),
        "fitted logliks diverge: dp {} vs adaptive {}",
        dp_fit.loglik,
        ad_fit.loglik
    );
}

#[test]
fn remap_every_one_recomputes_at_every_theta() {
    let f = field();
    let every_eval = cfg(Variant::Adaptive { tolerance: 1e-6 }, 1);
    let prob = MleProblem::new(&f.locations, &f.values, every_eval).unwrap();
    // three distinct thetas: every successful evaluation recomputes
    for theta in [
        MaternParams::new(1.0, 0.1, 0.5),
        MaternParams::new(0.7, 0.07, 0.6),
        MaternParams::new(1.4, 0.13, 0.45),
    ] {
        prob.loglik(&theta).unwrap();
    }
    let trace = prob.trace();
    assert_eq!(trace.iterations.len(), 3);
    assert_eq!(trace.remap_count(), 3, "remap_every = 1 must remap each eval");
    assert!(trace.iterations.iter().all(|it| it.diagonal_dp));
}

//! Deterministic-coverage acceptance tests for the work-stealing
//! scheduler: for every ready-queue policy, the same DAG at 1, 4 and 8
//! workers must execute every task exactly once and leave identical
//! final tile contents.  Lost wakeups, double-steals and dropped
//! enqueues all surface here as either a hang (missed task), a count
//! mismatch (double execution) or divergent contents (edge violation).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use mpcholesky::scheduler::{Access, Scheduler, SchedulerConfig, SchedulingPolicy, TaskGraph};
use mpcholesky::tile::TileId;

const TILES: usize = 17;
const TASKS: usize = 600;

fn tid(i: usize) -> TileId {
    TileId::new(i, i)
}

/// Seeded LCG so every run sees the same pseudo-random DAG.
struct Lcg(u64);
impl Lcg {
    fn next(&mut self) -> usize {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (self.0 >> 33) as usize
    }
}

/// The shared access pattern: task k reads/writes 1-3 tiles.  Returns
/// the accesses in submission order, deduplicated per task so a task
/// never declares the same tile twice.
fn accesses_for(k: usize, rng: &mut Lcg) -> Vec<(TileId, Access)> {
    let n_acc = 1 + rng.next() % 3;
    let mut acc: Vec<(TileId, Access)> = Vec::new();
    for _ in 0..n_acc {
        let tile = rng.next() % TILES;
        let mode = if rng.next() % 3 == 0 { Access::Write } else { Access::Read };
        if !acc.iter().any(|(t, _)| t.i == tile) {
            acc.push((tid(tile), mode));
        }
    }
    // make sure every task touches something and some tasks fan wide
    if k % 97 == 0 {
        for extra in 0..4 {
            let tile = (k / 97 + extra * 5) % TILES;
            if !acc.iter().any(|(t, _)| t.i == tile) {
                acc.push((tid(tile), Access::Write));
            }
        }
    }
    acc
}

fn build_graph() -> TaskGraph<usize> {
    let mut g: TaskGraph<usize> = TaskGraph::new();
    let mut rng = Lcg(0x5eed_cafe_d00d_f00d);
    for k in 0..TASKS {
        let acc = accesses_for(k, &mut rng);
        g.submit(k, acc);
    }
    // exercise the PrecisionFrontier tie-break with non-trivial ranks
    g.compute_cheapness(|&p| (p % 3) as u8);
    g
}

/// The deterministic per-tile update a writer applies: order-sensitive,
/// so any writer-order deviation between runs changes the final value.
fn mix(cell: u64, payload: usize) -> u64 {
    cell.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(payload as u64 + 1)
}

/// Serial reference: apply every write in program order.
fn reference_contents() -> Vec<u64> {
    let g = build_graph();
    let mut cells = vec![0u64; TILES];
    for (k, t) in g.tasks().iter().enumerate() {
        for &(res, mode) in &t.accesses {
            let tile = res.as_tile().expect("toy graph uses tile resources only");
            if mode == Access::Write {
                cells[tile.i] = mix(cells[tile.i], k);
            }
        }
    }
    cells
}

fn run_once(policy: SchedulingPolicy, workers: usize) -> (Vec<u64>, Vec<usize>) {
    let mut g = build_graph();
    let cells: Vec<AtomicU64> = (0..TILES).map(|_| AtomicU64::new(0)).collect();
    let runs: Vec<AtomicUsize> = (0..TASKS).map(|_| AtomicUsize::new(0)).collect();
    let sched = Scheduler::new(SchedulerConfig { num_workers: workers, policy, trace: false });
    let accesses: Vec<_> = g.tasks().iter().map(|t| t.accesses.clone()).collect();
    sched
        .run(&mut g, |idx, &payload| {
            runs[idx].fetch_add(1, Ordering::SeqCst);
            for &(res, mode) in &accesses[idx] {
                let tile = res.as_tile().expect("toy graph uses tile resources only");
                match mode {
                    // DAG edges serialize conflicting accesses, so a
                    // load/store pair (not a RMW) is race-free iff the
                    // scheduler is correct — a violation shows up as a
                    // wrong final value.
                    Access::Write => {
                        let old = cells[tile.i].load(Ordering::SeqCst);
                        cells[tile.i].store(mix(old, payload), Ordering::SeqCst);
                    }
                    Access::Read => {
                        std::hint::black_box(cells[tile.i].load(Ordering::SeqCst));
                    }
                }
            }
            Ok(())
        })
        .unwrap();
    (
        cells.iter().map(|c| c.load(Ordering::SeqCst)).collect(),
        runs.iter().map(|r| r.load(Ordering::SeqCst)).collect(),
    )
}

#[test]
fn every_policy_and_width_executes_each_task_once_with_identical_contents() {
    let want = reference_contents();
    for policy in [
        SchedulingPolicy::Fifo,
        SchedulingPolicy::Lifo,
        SchedulingPolicy::CriticalPath,
        SchedulingPolicy::PrecisionFrontier,
    ] {
        for workers in [1usize, 4, 8] {
            let (cells, runs) = run_once(policy, workers);
            for (k, &r) in runs.iter().enumerate() {
                assert_eq!(r, 1, "{policy:?}/{workers}w: task {k} ran {r} times");
            }
            assert_eq!(
                cells,
                want,
                "{policy:?}/{workers}w: final tile contents diverge from program order"
            );
        }
    }
}

#[test]
fn repeated_runs_are_reproducible_at_high_contention() {
    // same DAG, same policy, many runs: catches rare lost-wakeup /
    // double-steal interleavings that a single pass can miss
    let want = reference_contents();
    for _ in 0..5 {
        let (cells, runs) = run_once(SchedulingPolicy::PrecisionFrontier, 8);
        assert!(runs.iter().all(|&r| r == 1));
        assert_eq!(cells, want);
    }
}

/// The whole-iteration pipeline task kinds (`SolveFwd`/`SolveBwd` RHS
/// blocks, the `LogDetPartial` scalar chain, and the adaptive
/// `ResolvePanel`/`TrsmNative`/`SyrkNative` runtime-precision codelets)
/// under the same exactly-once / identical-results contract: a static
/// mixed-precision pipeline and a dynamic adaptive pipeline, every
/// policy, 1/4/8 workers — every task runs exactly once and the factor,
/// the solved RHS and the log-determinant are identical across runs.
#[test]
fn pipeline_plans_execute_exactly_once_with_identical_results() {
    use mpcholesky::cholesky::{
        GenContext, KernelCall, PanelResolver, PipelineBuffers, PipelineContext, PipelineOptions,
        PipelinePlan, TileExecutor, Variant,
    };
    use mpcholesky::kernels::NativeBackend;
    use mpcholesky::matern::{Location, MaternParams, Metric};
    use mpcholesky::rng::Xoshiro256pp;
    use mpcholesky::tile::{DenseMatrix, TileMatrix};

    let n = 160;
    let nb = 32;
    let p = n / nb;
    let theta = MaternParams::new(1.0, 0.1, 0.5);
    let mut r = Xoshiro256pp::seed_from_u64(7);
    let mut locs: Vec<Location> = (0..n)
        .map(|_| Location::new(r.uniform_open(0.0, 1.0), r.uniform_open(0.0, 1.0)))
        .collect();
    locs.sort_by(|a, b| (a.x + a.y).partial_cmp(&(b.x + b.y)).unwrap());
    let rhs: Vec<f64> = (0..n).map(|_| r.standard_normal()).collect();
    let opts = PipelineOptions { rhs_cols: 1, backward: true, logdet: true, ..Default::default() };

    for dynamic in [false, true] {
        let mut reference: Option<(DenseMatrix, Vec<f64>, f64)> = None;
        for policy in [
            SchedulingPolicy::Fifo,
            SchedulingPolicy::Lifo,
            SchedulingPolicy::CriticalPath,
            SchedulingPolicy::PrecisionFrontier,
        ] {
            for workers in [1usize, 4, 8] {
                let mut tiles = TileMatrix::zeros(n, nb).unwrap();
                let (mut plan, resolver) = if dynamic {
                    (
                        PipelinePlan::build_adaptive(p, nb, 1e-6, opts),
                        Some(PanelResolver::new(p, 1e-6)),
                    )
                } else {
                    let v = Variant::MixedPrecision { diag_thick: 2 };
                    let map = v.precision_map(p, None).unwrap();
                    tiles.apply_precision_map(&map);
                    (PipelinePlan::build_static(p, nb, v, map, opts), None)
                };
                let has = |pred: &dyn Fn(&KernelCall) -> bool| {
                    plan.graph.tasks().iter().any(|t| pred(&t.payload.call))
                };
                assert!(has(&|c| matches!(c, KernelCall::SolveFwd { .. })));
                assert!(has(&|c| matches!(c, KernelCall::SolveBwd { .. })));
                assert!(has(&|c| matches!(c, KernelCall::LogDetPartial { .. })));
                assert!(has(&|c| matches!(c, KernelCall::Generate { .. })));
                if dynamic {
                    assert!(has(&|c| matches!(c, KernelCall::ResolvePanel { .. })));
                    assert!(has(&|c| matches!(c, KernelCall::TrsmNative { .. })));
                    assert!(has(&|c| matches!(c, KernelCall::SyrkNative { .. })));
                }
                let mut bufs = PipelineBuffers::new(p, nb, 1, 0);
                bufs.load_column(0, &rhs);
                let n_tasks = plan.graph.len();
                let runs: Vec<AtomicUsize> = (0..n_tasks).map(|_| AtomicUsize::new(0)).collect();
                let accesses: Vec<_> =
                    plan.graph.tasks().iter().map(|t| t.accesses.clone()).collect();
                let exec = TileExecutor::new(&tiles, &NativeBackend)
                    .with_generation(GenContext {
                        locations: &locs,
                        theta,
                        metric: Metric::Euclidean,
                        nugget: 1e-8,
                    })
                    .with_pipeline(PipelineContext {
                        bufs: &bufs,
                        resolver: resolver.as_ref(),
                        crosscov: None,
                    });
                let sched =
                    Scheduler::new(SchedulerConfig { num_workers: workers, policy, trace: false });
                sched
                    .run(&mut plan.graph, |idx, sc| {
                        runs[idx].fetch_add(1, Ordering::SeqCst);
                        exec.execute(sc, &accesses[idx])
                    })
                    .unwrap();
                for (t, c) in runs.iter().enumerate() {
                    assert_eq!(
                        c.load(Ordering::SeqCst),
                        1,
                        "{policy:?}/{workers}w dynamic={dynamic}: task {t} run count"
                    );
                }
                let factor = tiles.to_dense(true);
                let solved = bufs.column(0);
                let logdet = bufs.logdet();
                if let Some((f0, s0, l0)) = &reference {
                    assert_eq!(
                        factor.max_abs_diff(f0),
                        0.0,
                        "{policy:?}/{workers}w dynamic={dynamic}: factor diverges"
                    );
                    assert_eq!(&solved, s0, "{policy:?}/{workers}w: solved RHS diverges");
                    assert_eq!(logdet, *l0, "{policy:?}/{workers}w: log-det diverges");
                } else {
                    reference = Some((factor, solved, logdet));
                }
            }
        }
    }
}

/// The fused `GemmBatch` task kind (wide access lists: 2 reads per
/// covered panel step + 1 write) under the same exactly-once /
/// identical-contents contract: a real fused factorization plan, every
/// policy, 1/4/8 workers, every task exactly once, bit-identical
/// factors across all runs.
#[test]
fn fused_gemm_batch_plans_execute_exactly_once_with_identical_factors() {
    use mpcholesky::cholesky::{CholeskyPlan, KernelCall, TileExecutor, Variant};
    use mpcholesky::kernels::NativeBackend;
    use mpcholesky::matern::{matern_matrix, Location, MaternParams, Metric};
    use mpcholesky::rng::Xoshiro256pp;
    use mpcholesky::tile::{DenseMatrix, TileMatrix};

    let n = 160;
    let nb = 32;
    let theta = MaternParams::new(1.0, 0.1, 0.5);
    let mut r = Xoshiro256pp::seed_from_u64(4);
    let mut locs: Vec<Location> = (0..n)
        .map(|_| Location::new(r.uniform_open(0.0, 1.0), r.uniform_open(0.0, 1.0)))
        .collect();
    locs.sort_by(|a, b| (a.x + a.y).partial_cmp(&(b.x + b.y)).unwrap());
    let a =
        DenseMatrix::from_vec(n, matern_matrix(&locs, &theta, Metric::Euclidean, 1e-8)).unwrap();
    let variant = Variant::MixedPrecision { diag_thick: 2 };

    let mut reference: Option<DenseMatrix> = None;
    for policy in [
        SchedulingPolicy::Fifo,
        SchedulingPolicy::Lifo,
        SchedulingPolicy::CriticalPath,
        SchedulingPolicy::PrecisionFrontier,
    ] {
        for workers in [1usize, 4, 8] {
            let mut tiles = TileMatrix::from_dense(&a, nb).unwrap();
            let map = variant.precision_map(n / nb, None).unwrap();
            tiles.apply_precision_map(&map);
            let mut plan = CholeskyPlan::build_fused(n / nb, nb, variant, map, false);
            let has_batch = plan
                .graph
                .tasks()
                .iter()
                .any(|t| matches!(t.payload.call, KernelCall::GemmBatch { .. }));
            assert!(has_batch, "plan must contain the new task kind");
            let n_tasks = plan.graph.len();
            let runs: Vec<AtomicUsize> = (0..n_tasks).map(|_| AtomicUsize::new(0)).collect();
            let accesses: Vec<_> = plan.graph.tasks().iter().map(|t| t.accesses.clone()).collect();
            let exec = TileExecutor::new(&tiles, &NativeBackend);
            let sched =
                Scheduler::new(SchedulerConfig { num_workers: workers, policy, trace: false });
            sched
                .run(&mut plan.graph, |idx, sc| {
                    runs[idx].fetch_add(1, Ordering::SeqCst);
                    exec.execute(sc, &accesses[idx])
                })
                .unwrap();
            for (k, r) in runs.iter().enumerate() {
                assert_eq!(
                    r.load(Ordering::SeqCst),
                    1,
                    "{policy:?}/{workers}w: task {k} run count"
                );
            }
            let factor = tiles.to_dense(true);
            if let Some(want) = &reference {
                assert_eq!(
                    factor.max_abs_diff(want),
                    0.0,
                    "{policy:?}/{workers}w: factor diverges"
                );
            } else {
                reference = Some(factor);
            }
        }
    }
}

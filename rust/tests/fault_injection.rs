//! Seeded fault-injection integration tests: the precision-escalation
//! retry ladder, NaN/bit-flip corruption at decode, forced codelet
//! panics/errors, worker kills and the scheduler watchdog — the proof
//! that a numerical breakdown or a runtime fault surfaces as a typed
//! `Err`, never a hang or a corrupted result.
//!
//! The `env_leg_*` tests are the CI fault-matrix entry points: each is a
//! no-op unless `PALLAS_INJECT` selects its fault kind, so one process
//! run per leg exercises exactly one ambient injection.
//!
//! The clean-failure cases at the bottom (typed mid-run errors, abort
//! drains on wide graphs, optimizer recovery from rejected regions,
//! artifact-corruption errors) were merged in from the former
//! `tests/failure_injection.rs` so every failure-path pin lives in one
//! suite.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use mpcholesky::cholesky::{factorize_tiles, CholeskyPlan, TileExecutor};
use mpcholesky::fault::{env_plan, FaultPlan, KillTarget, ENV_VAR};
use mpcholesky::kernels::TileBackend;
use mpcholesky::matern::matern_matrix;
use mpcholesky::predict::kfold_pmse_with_backend;
use mpcholesky::prelude::*;
use mpcholesky::tile::DenseMatrix;

/// A = M Mᵀ / n + eps·I with M a random n × (n/2) factor: exactly
/// rank-deficient before the ridge, so the smallest eigenvalue is
/// exactly `eps` and reduced-precision storage roundoff can push the
/// matrix indefinite on demand.
fn spd_tiles(n: usize, nb: usize, seed: u64, eps: f64) -> TileMatrix {
    let r = n / 2;
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let m: Vec<f64> = (0..n * r).map(|_| rng.standard_normal()).collect();
    let mut a = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = 0.0;
            for k in 0..r {
                s += m[i * r + k] * m[j * r + k];
            }
            s /= n as f64;
            a[i * n + j] = s;
            a[j * n + i] = s;
        }
        a[i * n + i] += eps;
    }
    let dense = DenseMatrix::from_vec(n, a).unwrap();
    TileMatrix::from_dense(&dense, nb).unwrap()
}

/// The acceptance scenario: demote the diagonal-adjacent panel to bf16
/// until the factorization breaks down, then show the escalation ladder
/// rescues it — and that the rescued factor is bit-identical to running
/// the escalated map directly.
#[test]
fn escalation_recovers_breakdown_bit_identical_to_direct_run() {
    use mpcholesky::tile::Precision;
    let (nb, p) = (32usize, 2usize);
    let n = nb * p;
    let hostile = PrecisionMap::from_fn(p, |i, j| if i == j { Precision::F64 } else { Precision::Bf16 });
    let variant = Variant::MixedPrecision { diag_thick: 1 };
    let sched = Scheduler::with_workers(2);

    // find a (seed, eps) whose bf16-demoted panel loses positive
    // definiteness (deterministic given the grid: each probe replays)
    let mut broken = None;
    'search: for seed in 1..8 {
        for eps in [1e-3, 1e-5, 1e-7, 1e-9] {
            let mut tiles = spd_tiles(n, nb, seed, eps);
            match factorize_tiles_with_opts(
                &mut tiles,
                variant,
                hostile.clone(),
                PlanOptions::default(),
                &NativeBackend,
                &sched,
            ) {
                Err(Error::NotPositiveDefinite { .. }) => {
                    broken = Some((seed, eps));
                    break 'search;
                }
                Ok(_) => {}
                Err(e) => panic!("unexpected failure probing seed={seed} eps={eps}: {e}"),
            }
        }
    }
    let (seed, eps) = broken.expect("no (seed, eps) in the grid broke the bf16 panel");

    // the retry ladder must promote its way to a clean factor
    let mut tiles = spd_tiles(n, nb, seed, eps);
    let (plan, trace) = factorize_tiles_with_recovery(
        &mut tiles,
        variant,
        hostile.clone(),
        PlanOptions::default(),
        RecoveryOptions::default(),
        &NativeBackend,
        &sched,
    )
    .expect("escalation ladder failed to rescue the breakdown");
    assert!(trace.attempts >= 1, "recovery must have retried");
    assert!(trace.escalated_tiles >= 1);
    assert!(trace.map_churn >= 1, "the final map must differ from the requested one");
    assert_eq!(trace.map_churn, hostile.churn(&plan.map));

    // bit-identical to requesting the escalated map directly
    let mut direct = spd_tiles(n, nb, seed, eps);
    factorize_tiles_with_opts(
        &mut direct,
        variant,
        plan.map.clone(),
        PlanOptions::default(),
        &NativeBackend,
        &sched,
    )
    .expect("the escalated map must factor directly");
    let (a, b) = (tiles.to_dense(true), direct.to_dense(true));
    for j in 0..n {
        for i in j..n {
            assert_eq!(
                a.get(i, j).to_bits(),
                b.get(i, j).to_bits(),
                "rescued factor differs from the direct escalated-map run at ({i},{j})"
            );
        }
    }
}

/// Budget 0 disables recovery: the breakdown propagates unchanged.
#[test]
fn zero_retry_budget_propagates_the_breakdown() {
    use mpcholesky::tile::Precision;
    let (nb, p) = (32usize, 2usize);
    let n = nb * p;
    let hostile = PrecisionMap::from_fn(p, |i, j| if i == j { Precision::F64 } else { Precision::Bf16 });
    let sched = Scheduler::with_workers(2);
    for seed in 1..8 {
        let mut tiles = spd_tiles(n, nb, seed, 1e-9);
        let r = factorize_tiles_with_recovery(
            &mut tiles,
            Variant::MixedPrecision { diag_thick: 1 },
            hostile.clone(),
            PlanOptions::default(),
            RecoveryOptions { max_retries: 0 },
            &NativeBackend,
            &sched,
        );
        match r {
            Err(Error::NotPositiveDefinite { .. }) => return, // propagated, as required
            Ok((_, trace)) => assert_eq!(trace.attempts, 0, "budget 0 must never retry"),
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    panic!("no seed in the grid broke the bf16 panel");
}

/// NaN corruption of every decoded reduced-precision tile must surface
/// as the typed breakdown error (the potrf pivot check is NaN-safe),
/// not a hang or a silent wrong factor.
#[test]
fn nan_injection_at_decode_breaks_down_as_not_positive_definite() {
    let (nb, p) = (64usize, 4usize);
    let n = nb * p;
    let variant = Variant::ThreePrecision { dp_thick: 1, sp_thick: 1 };
    let map = variant.precision_map(p, None).unwrap();
    let mut tiles = spd_tiles(n, nb, 9, 0.5);
    tiles.apply_precision_map(&map);
    let mut plan = CholeskyPlan::build_with_opts(p, nb, variant, map, false, PlanOptions::default());
    let accesses: Vec<_> = plan.graph.tasks().iter().map(|t| t.accesses.clone()).collect();
    let faults = Arc::new(FaultPlan::default().with_nan(1.0, 7));
    let exec = TileExecutor::new(&tiles, &NativeBackend).with_faults(Some(faults));
    let sched = Scheduler::with_workers(4);
    match sched.run(&mut plan.graph, |idx, sc| exec.execute(sc, &accesses[idx])) {
        Err(Error::NotPositiveDefinite { pivot, .. }) => {
            assert!(pivot.is_nan() || pivot <= 0.0, "pivot {pivot} should be non-positive or NaN")
        }
        Ok(_) => panic!("rate-1.0 NaN decode injection must break the factorization"),
        Err(e) => panic!("expected NotPositiveDefinite, got: {e}"),
    }
}

/// An injected codelet panic becomes `Error::TaskPanicked` — with the
/// watchdog off and on, under 8 workers, and promptly.
#[test]
fn injected_codelet_panic_surfaces_as_task_panicked() {
    let (nb, p) = (32usize, 4usize);
    let n = nb * p;
    for deadline in [None, Some(Duration::from_secs(60))] {
        let tiles = spd_tiles(n, nb, 3, 0.5);
        let map = Variant::FullDp.precision_map(p, None).unwrap();
        let mut plan =
            CholeskyPlan::build_with_opts(p, nb, Variant::FullDp, map, false, PlanOptions::default());
        let accesses: Vec<_> = plan.graph.tasks().iter().map(|t| t.accesses.clone()).collect();
        // fresh plan per run: the nth-occurrence trigger fires once
        let faults = Arc::new(FaultPlan::default().with_panic_call("dgemm", 0));
        let exec = TileExecutor::new(&tiles, &NativeBackend).with_faults(Some(faults));
        let sched =
            Scheduler::new(SchedulerConfig { num_workers: 8, deadline, ..Default::default() });
        let t0 = Instant::now();
        match sched.run(&mut plan.graph, |idx, sc| exec.execute(sc, &accesses[idx])) {
            Err(Error::TaskPanicked { message, .. }) => {
                assert!(message.contains("injected panic"), "unexpected message: {message}")
            }
            Ok(_) => panic!("injected panic must fail the run (deadline {deadline:?})"),
            Err(e) => panic!("expected TaskPanicked, got: {e}"),
        }
        assert!(t0.elapsed() < Duration::from_secs(20), "abort drain too slow: {:?}", t0.elapsed());
    }
}

/// An injected worker kill becomes a typed `Err` — watchdog off and on,
/// 8 workers, never a hang.
#[test]
fn injected_worker_kill_surfaces_as_err() {
    let (nb, p) = (32usize, 4usize);
    let n = nb * p;
    for deadline in [None, Some(Duration::from_secs(60))] {
        let tiles = spd_tiles(n, nb, 3, 0.5);
        let map = Variant::FullDp.precision_map(p, None).unwrap();
        let mut plan =
            CholeskyPlan::build_with_opts(p, nb, Variant::FullDp, map, false, PlanOptions::default());
        let accesses: Vec<_> = plan.graph.tasks().iter().map(|t| t.accesses.clone()).collect();
        let exec = TileExecutor::new(&tiles, &NativeBackend);
        let faults = Arc::new(FaultPlan::default().with_kill(KillTarget::Any));
        let sched = Scheduler::new(SchedulerConfig {
            num_workers: 8,
            deadline,
            faults: Some(faults),
            ..Default::default()
        });
        let t0 = Instant::now();
        match sched.run(&mut plan.graph, |idx, sc| exec.execute(sc, &accesses[idx])) {
            Err(Error::FaultInjected(msg)) => {
                assert!(msg.contains("killed"), "unexpected message: {msg}")
            }
            Ok(_) => panic!("a killed worker must fail the run (deadline {deadline:?})"),
            Err(e) => panic!("expected FaultInjected, got: {e}"),
        }
        assert!(t0.elapsed() < Duration::from_secs(20), "abort drain too slow: {:?}", t0.elapsed());
    }
}

/// Backend wrapper failing the Nth DP potrf with a chosen sentinel pivot
/// — a numeric fault deep inside a scheduled run.
struct BrokenPotrf {
    inner: NativeBackend,
    fail_at: usize,
    pivot: f64,
    count: AtomicUsize,
}

impl TileBackend for BrokenPotrf {
    fn potrf_f64(&self, a: &mut [f64], nb: usize, row0: usize) -> Result<()> {
        if self.count.fetch_add(1, Ordering::SeqCst) == self.fail_at {
            return Err(Error::NotPositiveDefinite { pivot: self.pivot, index: row0 });
        }
        self.inner.potrf_f64(a, nb, row0)
    }
    fn potrf_f32(&self, a: &mut [f32], nb: usize, row0: usize) -> Result<()> {
        self.inner.potrf_f32(a, nb, row0)
    }
    fn trsm_f64(&self, l: &[f64], b: &mut [f64], nb: usize) {
        self.inner.trsm_f64(l, b, nb)
    }
    fn trsm_f32(&self, l: &[f32], b: &mut [f32], nb: usize) {
        self.inner.trsm_f32(l, b, nb)
    }
    fn syrk_f64(&self, c: &mut [f64], a: &[f64], nb: usize) {
        self.inner.syrk_f64(c, a, nb)
    }
    fn syrk_f32(&self, c: &mut [f32], a: &[f32], nb: usize) {
        self.inner.syrk_f32(c, a, nb)
    }
    fn gemm_f64(&self, c: &mut [f64], a: &[f64], b: &[f64], nb: usize) {
        self.inner.gemm_f64(c, a, b, nb)
    }
    fn gemm_f32(&self, c: &mut [f32], a: &[f32], b: &[f32], nb: usize) {
        self.inner.gemm_f32(c, a, b, nb)
    }
    fn name(&self) -> &'static str {
        "broken-potrf"
    }
}

/// A `NotPositiveDefinite` raised mid-pipeline aborts the whole merged
/// k-fold graph cleanly under 1/4/8 workers, and a clean rerun on the
/// same inputs is deterministic — no scratch leaks across the abort.
#[test]
fn kfold_abort_drains_cleanly_across_worker_counts() {
    let theta = MaternParams::new(1.0, 0.1, 0.5);
    let f = SyntheticField::generate(&FieldConfig { n: 256, theta, seed: 5, ..Default::default() })
        .unwrap();
    let mut reference: Option<Vec<u64>> = None;
    for workers in [1usize, 4, 8] {
        let cfg = MleConfig {
            nb: 64,
            num_workers: workers,
            variant: Variant::MixedPrecision { diag_thick: 2 },
            ..Default::default()
        };
        let be = BrokenPotrf {
            inner: NativeBackend,
            fail_at: 0,
            pivot: -2.0,
            count: AtomicUsize::new(0),
        };
        let t0 = Instant::now();
        match kfold_pmse_with_backend(&f.locations, &f.values, theta, 2, &cfg, 7, &be) {
            Err(Error::NotPositiveDefinite { pivot, .. }) => assert_eq!(pivot, -2.0),
            Ok(rep) => panic!("workers={workers}: expected abort, got pmse {}", rep.mean_pmse),
            Err(e) => panic!("workers={workers}: expected NotPositiveDefinite, got: {e}"),
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "workers={workers}: abort drain took {:?}",
            t0.elapsed()
        );
        // clean rerun on the same inputs: the abort left nothing behind
        let rep = kfold_pmse_with_backend(&f.locations, &f.values, theta, 2, &cfg, 7, &NativeBackend)
            .expect("clean rerun after abort");
        let bits: Vec<u64> = rep.fold_pmse.iter().map(|v| v.to_bits()).collect();
        match &reference {
            None => reference = Some(bits),
            Some(want) => {
                assert_eq!(want, &bits, "workers={workers}: k-fold result must be deterministic")
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Clean-failure cases (merged from the former tests/failure_injection.rs):
// the system must fail *cleanly* — typed errors, no partial-state
// corruption, optimizer recovery — under the error modes the paper's
// SSVIII.D discusses and a few it doesn't.
// ---------------------------------------------------------------------------

fn matern_tiles(n: usize, nb: usize, seed: u64) -> TileMatrix {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut locs: Vec<Location> = (0..n)
        .map(|_| Location::new(r.uniform_open(0.0, 1.0), r.uniform_open(0.0, 1.0)))
        .collect();
    mpcholesky::datagen::morton_sort(&mut locs);
    let a = DenseMatrix::from_vec(
        n,
        matern_matrix(&locs, &MaternParams::new(1.0, 0.05, 0.5), Metric::Euclidean, 1e-8),
    )
    .unwrap();
    TileMatrix::from_dense(&a, nb).unwrap()
}

#[test]
fn mid_run_kernel_failure_propagates_typed_error() {
    for fail_at in [0, 1, 3] {
        let be = BrokenPotrf {
            inner: NativeBackend,
            fail_at,
            pivot: -1.0,
            count: AtomicUsize::new(0),
        };
        let mut tiles = matern_tiles(256, 64, 1);
        let sched = Scheduler::with_workers(2);
        match factorize_tiles(&mut tiles, Variant::FullDp, &be, &sched) {
            Err(Error::NotPositiveDefinite { pivot, index }) => {
                assert_eq!(pivot, -1.0);
                assert_eq!(index, fail_at * 64, "failure reports the right tile");
            }
            other => panic!("fail_at={fail_at}: expected typed failure, got {other:?}"),
        }
    }
}

#[test]
fn failure_does_not_hang_wide_graphs() {
    // failure at the very first potrf of a large graph: every dependent
    // task must be drained without deadlock, quickly
    let be = BrokenPotrf {
        inner: NativeBackend,
        fail_at: 0,
        pivot: -1.0,
        count: AtomicUsize::new(0),
    };
    let mut tiles = matern_tiles(1024, 64, 2);
    let sched = Scheduler::with_workers(4);
    let t0 = Instant::now();
    assert!(factorize_tiles(&mut tiles, Variant::MixedPrecision { diag_thick: 2 }, &be, &sched)
        .err()
        .is_some());
    assert!(t0.elapsed().as_secs_f64() < 5.0, "drain took {:?}", t0.elapsed());
}

#[test]
fn optimizer_recovers_from_rejected_regions() {
    // Bounds that include a region where the DST covariance loses PD:
    // the fit must still converge to a finite answer by rejecting those
    // evaluations (the paper's SP(100%)/DST failure handling).
    let f = SyntheticField::generate(&FieldConfig {
        n: 256,
        theta: MaternParams::new(1.0, 0.05, 0.5),
        seed: 3,
        ..Default::default()
    })
    .unwrap();
    let cfg = MleConfig {
        nb: 64,
        variant: Variant::Dst { diag_thick: 2 },
        // wide range bound: large ranges make the banded matrix non-PD
        lower: [0.1, 0.005, 0.3],
        upper: [10.0, 1.0, 1.0],
        start: Some([1.0, 0.02, 0.5]),
        optimizer: mpcholesky::mle::OptimizerConfig { max_evals: 60, ..Default::default() },
        ..Default::default()
    };
    let fit = MleProblem::new(&f.locations, &f.values, cfg).unwrap().fit().unwrap();
    assert!(fit.loglik.is_finite());
    assert!(fit.theta.range < 0.5, "optimizer should stay in the PD region: {:?}", fit.theta);
}

#[test]
fn sp100_equivalent_fails_as_paper_describes() {
    // The paper excludes SP(100%) because "the covariance matrix may lose
    // the numerical property of positive definiteness".  Our analog: a
    // strongly correlated matrix squeezed through bf16 far bands with a
    // *zero-width* DP band is at risk; with diag_thick >= 1 the potrf
    // chain stays DP and must succeed even when far bands are bf16.
    let mut tiles = matern_tiles(320, 64, 4);
    let sched = Scheduler::with_workers(2);
    let r = factorize_tiles(
        &mut tiles,
        Variant::ThreePrecision { dp_thick: 1, sp_thick: 2 },
        &NativeBackend,
        &sched,
    );
    assert!(
        r.is_ok(),
        "DP diagonal band must keep the factorization alive: {:?}",
        r.err().map(|e| e.to_string())
    );
}

#[test]
fn corrupted_artifacts_dir_reports_artifact_error() {
    let r = mpcholesky::runtime::PjrtBackend::load("/nonexistent/path");
    match r {
        Err(Error::Artifact(msg)) => assert!(msg.contains("manifest")),
        other => panic!("expected Artifact error, got {:?}", other.err().map(|e| e.to_string())),
    }
}

#[test]
fn truncated_manifest_rejected() {
    let dir = std::env::temp_dir().join("mpchol_bad_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), "# nb=64\ngemm_f64\tbroken").unwrap();
    match mpcholesky::runtime::Manifest::load(&dir) {
        Err(Error::Artifact(_)) => {}
        other => panic!("expected Artifact error, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// CI fault-matrix legs: each test is a no-op unless PALLAS_INJECT selects
// its fault kind, so `cargo test -- env_leg` under one spec exercises
// exactly one ambient injection path end to end.
// ---------------------------------------------------------------------------

fn env_spec() -> Option<String> {
    std::env::var(ENV_VAR).ok().filter(|s| !s.trim().is_empty())
}

#[test]
fn env_leg_nan_decode_corruption() {
    let Some(spec) = env_spec() else { return };
    if !spec.starts_with("nan") {
        return;
    }
    assert!(env_plan().is_some(), "spec {spec:?} failed to parse — fix the CI leg");
    let variant = Variant::ThreePrecision { dp_thick: 1, sp_thick: 1 };
    let mut tiles = spd_tiles(256, 64, 9, 0.5);
    let sched = Scheduler::with_workers(4);
    match factorize_tiles(&mut tiles, variant, &NativeBackend, &sched) {
        Err(Error::NotPositiveDefinite { .. }) => {}
        Ok(_) => panic!("ambient NaN injection must break the bf16 factorization"),
        Err(e) => panic!("expected NotPositiveDefinite, got: {e}"),
    }
}

#[test]
fn env_leg_forced_task_error() {
    let Some(spec) = env_spec() else { return };
    if !spec.starts_with("error") {
        return;
    }
    assert!(env_plan().is_some(), "spec {spec:?} failed to parse — fix the CI leg");
    let mut tiles = spd_tiles(128, 32, 3, 0.5);
    let sched = Scheduler::with_workers(4);
    match factorize_tiles(&mut tiles, Variant::FullDp, &NativeBackend, &sched) {
        Err(Error::FaultInjected(msg)) => assert!(msg.contains("forced failure")),
        Ok(_) => panic!("ambient forced-error injection must fail the run"),
        Err(e) => panic!("expected FaultInjected, got: {e}"),
    }
}

#[test]
fn env_leg_worker_kill() {
    let Some(spec) = env_spec() else { return };
    if !spec.starts_with("kill") {
        return;
    }
    assert!(env_plan().is_some(), "spec {spec:?} failed to parse — fix the CI leg");
    let mut tiles = spd_tiles(128, 32, 3, 0.5);
    let sched = Scheduler::with_workers(4);
    let t0 = Instant::now();
    match factorize_tiles(&mut tiles, Variant::FullDp, &NativeBackend, &sched) {
        Err(Error::FaultInjected(msg)) => assert!(msg.contains("killed")),
        Ok(_) => panic!("ambient worker-kill injection must fail the run"),
        Err(e) => panic!("expected FaultInjected, got: {e}"),
    }
    assert!(t0.elapsed() < Duration::from_secs(30), "kill drain took {:?}", t0.elapsed());
}

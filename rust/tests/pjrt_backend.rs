//! Integration: the PJRT backend (AOT JAX/Pallas HLO artifacts through
//! the xla crate) must agree tile-for-tile with the native Rust backend,
//! and full factorizations through PJRT must match.
//!
//! Requires `make artifacts` (skips with a message otherwise — CI runs
//! artifacts first).

// The whole suite needs the real PJRT client, which only exists behind
// the `pjrt` cargo feature (the hermetic default build ships a stub).
#![cfg(feature = "pjrt")]

use mpcholesky::cholesky::{factorize_dense, Variant};
use mpcholesky::kernels::{NativeBackend, TileBackend};
use mpcholesky::matern::{Location, MaternParams, Metric};
use mpcholesky::rng::Xoshiro256pp;
use mpcholesky::runtime::PjrtBackend;
use mpcholesky::scheduler::Scheduler;
use mpcholesky::tile::DenseMatrix;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("MPCHOL_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    });
    if std::path::Path::new(&dir).join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping PJRT tests: {dir}/manifest.txt missing (run `make artifacts`)");
        None
    }
}

fn backend() -> Option<PjrtBackend> {
    artifacts_dir().map(|d| PjrtBackend::load(d).expect("artifact load failed"))
}

fn rand_tile(nb: usize, seed: u64, scale: f64) -> Vec<f64> {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    (0..nb * nb).map(|_| r.standard_normal() * scale).collect()
}

fn spd_tile(nb: usize, seed: u64) -> Vec<f64> {
    let b = rand_tile(nb, seed, 1.0);
    let mut a = vec![0.0; nb * nb];
    for j in 0..nb {
        for i in 0..nb {
            let mut s = 0.0;
            for k in 0..nb {
                s += b[i + k * nb] * b[j + k * nb];
            }
            a[i + j * nb] = s + if i == j { nb as f64 } else { 0.0 };
        }
    }
    a
}

#[test]
fn gemm_parity_f64() {
    let Some(be) = backend() else { return };
    let nb = be.nb();
    let a = rand_tile(nb, 1, 1.0);
    let b = rand_tile(nb, 2, 1.0);
    let mut c1 = rand_tile(nb, 3, 1.0);
    let mut c2 = c1.clone();
    be.gemm_f64(&mut c1, &a, &b, nb);
    NativeBackend.gemm_f64(&mut c2, &a, &b, nb);
    for (x, y) in c1.iter().zip(c2.iter()) {
        assert!((x - y).abs() < 1e-10, "{x} vs {y}");
    }
}

#[test]
fn gemm_parity_f32() {
    let Some(be) = backend() else { return };
    let nb = be.nb();
    let a: Vec<f32> = rand_tile(nb, 4, 1.0).iter().map(|&x| x as f32).collect();
    let b: Vec<f32> = rand_tile(nb, 5, 1.0).iter().map(|&x| x as f32).collect();
    let mut c1: Vec<f32> = rand_tile(nb, 6, 1.0).iter().map(|&x| x as f32).collect();
    let mut c2 = c1.clone();
    be.gemm_f32(&mut c1, &a, &b, nb);
    NativeBackend.gemm_f32(&mut c2, &a, &b, nb);
    for (x, y) in c1.iter().zip(c2.iter()) {
        assert!(
            (x - y).abs() < 1e-3 * nb as f32,
            "f32 accumulation-order tolerance exceeded: {x} vs {y}"
        );
    }
}

#[test]
fn syrk_parity() {
    let Some(be) = backend() else { return };
    let nb = be.nb();
    let a = rand_tile(nb, 7, 1.0);
    let mut c1 = rand_tile(nb, 8, 1.0);
    let mut c2 = c1.clone();
    be.syrk_f64(&mut c1, &a, nb);
    NativeBackend.syrk_f64(&mut c2, &a, nb);
    // native syrk only touches the lower triangle; compare there
    for j in 0..nb {
        for i in j..nb {
            let (x, y) = (c1[i + j * nb], c2[i + j * nb]);
            assert!((x - y).abs() < 1e-10, "({i},{j}): {x} vs {y}");
        }
    }
}

#[test]
fn trsm_parity() {
    let Some(be) = backend() else { return };
    let nb = be.nb();
    let mut l = spd_tile(nb, 9);
    NativeBackend.potrf_f64(&mut l, nb, 0).unwrap();
    let mut b1 = rand_tile(nb, 10, 1.0);
    let mut b2 = b1.clone();
    be.trsm_f64(&l, &mut b1, nb);
    NativeBackend.trsm_f64(&l, &mut b2, nb);
    for (x, y) in b1.iter().zip(b2.iter()) {
        assert!((x - y).abs() < 1e-9, "{x} vs {y}");
    }
}

#[test]
fn potrf_parity() {
    let Some(be) = backend() else { return };
    let nb = be.nb();
    let a = spd_tile(nb, 11);
    let mut l1 = a.clone();
    let mut l2 = a.clone();
    be.potrf_f64(&mut l1, nb, 0).unwrap();
    NativeBackend.potrf_f64(&mut l2, nb, 0).unwrap();
    for (x, y) in l1.iter().zip(l2.iter()) {
        assert!((x - y).abs() < 1e-9, "{x} vs {y}");
    }
}

#[test]
fn potrf_detects_indefinite() {
    let Some(be) = backend() else { return };
    let nb = be.nb();
    let mut a = vec![0.0; nb * nb];
    for i in 0..nb {
        a[i + i * nb] = 1.0;
    }
    a[2 + 2 * nb] = -5.0;
    assert!(be.potrf_f64(&mut a, nb, 0).is_err());
}

#[test]
fn matern_parity_halfint() {
    let Some(be) = backend() else { return };
    let nb = be.nb();
    let mut r = Xoshiro256pp::seed_from_u64(12);
    let locs: Vec<Location> =
        (0..nb).map(|_| Location::new(r.uniform(), r.uniform())).collect();
    for nu in [0.5, 1.5, 2.5] {
        let th = MaternParams::new(1.3, 0.12, nu);
        let mut o1 = vec![0.0; nb * nb];
        let mut o2 = vec![0.0; nb * nb];
        be.matern_f64(&mut o1, &locs, &locs, &th, Metric::Euclidean);
        NativeBackend.matern_f64(&mut o2, &locs, &locs, &th, Metric::Euclidean);
        for (x, y) in o1.iter().zip(o2.iter()) {
            assert!((x - y).abs() < 1e-11, "nu={nu}: {x} vs {y}");
        }
    }
}

#[test]
fn matern_general_nu_falls_back_to_native() {
    let Some(be) = backend() else { return };
    let nb = be.nb();
    let locs: Vec<Location> = (0..nb)
        .map(|i| Location::new(i as f64 / nb as f64, 0.5))
        .collect();
    let th = MaternParams::new(1.0, 0.1, 1.27); // non-half-integer
    let mut o1 = vec![0.0; nb * nb];
    let mut o2 = vec![0.0; nb * nb];
    be.matern_f64(&mut o1, &locs, &locs, &th, Metric::Euclidean);
    NativeBackend.matern_f64(&mut o2, &locs, &locs, &th, Metric::Euclidean);
    assert_eq!(o1, o2);
}

/// The headline integration check: a full mixed-precision factorization
/// executed entirely through the PJRT artifacts matches the native one.
#[test]
fn full_factorization_through_pjrt_matches_native() {
    let Some(be) = backend() else { return };
    let nb = be.nb();
    let p = 4;
    let n = nb * p;
    // matern covariance over a locality-ordered site set
    let mut r = Xoshiro256pp::seed_from_u64(13);
    let mut locs: Vec<Location> =
        (0..n).map(|_| Location::new(r.uniform(), r.uniform())).collect();
    mpcholesky::datagen::morton_sort(&mut locs);
    let th = MaternParams::new(1.0, 0.1, 0.5);
    let buf = mpcholesky::matern::matern_matrix(&locs, &th, Metric::Euclidean, 1e-6);
    let a = DenseMatrix::from_vec(n, buf).unwrap();
    // DST needs weakly-correlated data: zeroing off-band blocks of a
    // strongly-correlated covariance loses positive definiteness (the
    // paper's own DST failure mode, SSVIII.D.1)
    let th_weak = MaternParams::new(1.0, 0.02, 0.5);
    let buf_w = mpcholesky::matern::matern_matrix(&locs, &th_weak, Metric::Euclidean, 1e-6);
    let a_weak = DenseMatrix::from_vec(n, buf_w).unwrap();

    let sched = Scheduler::with_workers(2);
    for variant in [
        Variant::FullDp,
        Variant::MixedPrecision { diag_thick: 2 },
        Variant::Dst { diag_thick: 2 },
    ] {
        let m = if matches!(variant, Variant::Dst { .. }) { &a_weak } else { &a };
        let tp = factorize_dense(m, nb, variant, &be, &sched).unwrap();
        let tn = factorize_dense(m, nb, variant, &NativeBackend, &sched).unwrap();
        let (dp, dn) = (tp.to_dense(true), tn.to_dense(true));
        let diff = dp.max_abs_diff(&dn);
        let tol = match variant {
            // SP work reorders accumulation between backends
            Variant::MixedPrecision { .. } => 1e-4,
            _ => 1e-8,
        };
        assert!(diff < tol, "{variant:?}: backend divergence {diff}");
    }
}

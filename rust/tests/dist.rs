//! End-to-end multi-process distributed runtime tests: real `mpchol
//! dist` invocations, real spawned worker processes, real loopback TCP
//! between them.  The in-crate unit tests cover the same protocol
//! in-process; these pin the full binary path — CLI flag round-trip,
//! worker re-invocation via `current_exe`, and the printed `DIST`
//! summary the CI smoke job parses.

use std::collections::HashMap;
use std::process::Command;

/// Run `mpchol dist <args>`, assert success, and parse the `DIST`
/// `key=value` summary lines.
fn run_dist(args: &[&str]) -> HashMap<String, String> {
    let out = Command::new(env!("CARGO_BIN_EXE_mpchol"))
        .arg("dist")
        .args(args)
        .output()
        .expect("spawn mpchol");
    assert!(
        out.status.success(),
        "mpchol dist {args:?} failed ({}):\n{}",
        out.status,
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8(out.stdout).expect("utf8 stdout");
    let mut kv = HashMap::new();
    for line in stdout.lines() {
        if let Some(rest) = line.strip_prefix("DIST ") {
            for tok in rest.split_whitespace() {
                if let Some((k, v)) = tok.split_once('=') {
                    kv.insert(k.to_string(), v.to_string());
                }
            }
        }
    }
    assert!(!kv.is_empty(), "no DIST summary lines in output:\n{stdout}");
    kv
}

fn int(kv: &HashMap<String, String>, key: &str) -> u64 {
    kv[key].parse().unwrap_or_else(|_| panic!("{key}={:?} is not an integer", kv[key]))
}

/// `mpchol dist` argument list for a small instance: `--ranks <ranks>`
/// plus the variant-specific `extra` flags.
fn dist_args<'a>(ranks: &'a str, extra: &[&'a str]) -> Vec<&'a str> {
    let mut args = vec!["--ranks", ranks, "--n", "128", "--nb", "32", "--workers", "2"];
    args.extend_from_slice(extra);
    args
}

#[test]
fn multi_process_factorization_is_bitwise_identical_to_single() {
    let mp = ["--variant", "mp", "--thick", "2"];
    let single = run_dist(&dist_args("1", &mp));
    assert_eq!(int(&single, "wire_msgs"), 0);
    assert_eq!(single["max_resident"], single["single_resident"]);

    for ranks in ["2", "4"] {
        let kv = run_dist(&dist_args(ranks, &mp));
        // the tentpole acceptance criterion: same realized map, same
        // bits, no matter how many processes computed the factor
        assert_eq!(kv["digest"], single["digest"], "ranks={ranks}");
        // observed frames == partition census == analytic simulator
        assert_eq!(kv["census_match"], "true", "ranks={ranks}");
        assert!(int(&kv, "wire_msgs") > 0, "ranks={ranks}");
        // tiles crossed at stored precision, beating the all-f64 wire
        assert!(int(&kv, "wire_bytes") < int(&kv, "f64_wire_bytes"), "ranks={ranks}: {kv:?}");
        // every rank held strictly less than the whole triangle
        assert!(int(&kv, "max_resident") < int(&kv, "single_resident"), "ranks={ranks}: {kv:?}");
    }
}

#[test]
fn adaptive_map_resolves_identically_across_the_mesh() {
    // the data-dependent variant exercises the pre-factorization norm
    // all-gather: every rank must realize the same map, hence the same
    // factor bits, from only its owned tiles plus the gathered norms
    let adaptive = ["--variant", "adaptive", "--tolerance", "1e-3"];
    let single = run_dist(&dist_args("1", &adaptive));
    let dist = run_dist(&dist_args("2", &adaptive));
    assert_eq!(dist["digest"], single["digest"]);
    assert_eq!(dist["variant"], single["variant"], "realized adaptive labels must agree");
    assert_eq!(dist["census_match"], "true");
    assert!(int(&dist, "wire_bytes") < int(&dist, "f64_wire_bytes"));
}

#[test]
fn tlr_distributed_runs_are_rejected_with_a_typed_error() {
    let out = Command::new(env!("CARGO_BIN_EXE_mpchol"))
        .args(["dist", "--ranks", "2", "--n", "128", "--nb", "32", "--variant", "tlr"])
        .output()
        .expect("spawn mpchol");
    assert!(!out.status.success(), "tlr dist run must fail up front");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("tlr"), "unexpected error output: {stderr}");
}

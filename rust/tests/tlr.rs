//! TLR (tile low-rank) storage-class suite — the oracle-bounded pins for
//! the compressed tier:
//!
//! * every rank-aware kernel stays within the documented tol-derived
//!   backward-error bound of its dense oracle;
//! * compression obeys `||A - U V^T||_F <= tol * ||A||_F` across a
//!   Matérn theta sweep, rank is monotone nonincreasing in the tolerance,
//!   and a full-rank budget roundtrips bitwise;
//! * on a band-dominant map the compressed factor's resident bytes land
//!   strictly below the all-bf16 floor;
//! * the TLR factorization is bit-deterministic across 1/4/8 workers and
//!   all four scheduling policies;
//! * a breakdown inside a compressed panel climbs the recovery ladder
//!   (LowRank -> f32 -> f64) and the rescued factor is bit-identical to
//!   factoring under the escalated map directly;
//! * the paper's independent-blocks baseline is qualitatively less
//!   accurate than TLR at the same block size.

use mpcholesky::cholesky::{factorize_tiles, factorize_tiles_with_map, Variant};
use mpcholesky::kernels::{lowrank, NativeBackend, TileBackend};
use mpcholesky::matern::{matern_matrix, Location, MaternParams, Metric};
use mpcholesky::prelude::*;
use mpcholesky::tile::{DenseMatrix, Precision, TileId, TileMatrix};

fn frob(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

fn frob_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b.iter()).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

fn frob_diff_lower(a: &[f64], b: &[f64], nb: usize) -> f64 {
    let mut acc = 0.0;
    for j in 0..nb {
        for i in j..nb {
            let d = a[i + j * nb] - b[i + j * nb];
            acc += d * d;
        }
    }
    acc.sqrt()
}

/// Collinear 1D sites: with the exponential kernel (nu = 1/2) every
/// strictly-off-diagonal tile is mathematically rank 1
/// (`exp(-(x_i - x_j)/theta) = exp(-x_i/theta) * exp(x_j/theta)` once the
/// sites are sorted), the band-dominant scenario of the byte-floor pins.
fn locs_1d(n: usize) -> Vec<Location> {
    (0..n).map(|i| Location::new(i as f64 / n as f64, 0.0)).collect()
}

fn locs_2d(n: usize, seed: u64) -> Vec<Location> {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut locs: Vec<Location> = (0..n)
        .map(|_| Location::new(r.uniform_open(0.0, 1.0), r.uniform_open(0.0, 1.0)))
        .collect();
    mpcholesky::datagen::morton_sort(&mut locs);
    locs
}

fn matern_tiles(locs: &[Location], theta: MaternParams, nb: usize) -> TileMatrix {
    let n = locs.len();
    let a =
        DenseMatrix::from_vec(n, matern_matrix(locs, &theta, Metric::Euclidean, 1e-8)).unwrap();
    TileMatrix::from_dense(&a, nb).unwrap()
}

/// `max_{i>=j} |(L L^T)_{ij} - A_{ij}|` — the reconstruction backward
/// error of a factored tile matrix against the original covariance.
fn reconstruction_err(tiles: &TileMatrix, a: &DenseMatrix, n: usize) -> f64 {
    let l = tiles.to_dense(true);
    let mut worst = 0.0f64;
    for j in 0..n {
        for i in j..n {
            let mut s = 0.0;
            for k in 0..=j {
                s += l.get(i, k) * l.get(j, k);
            }
            worst = worst.max((s - a.get(i, j)).abs());
        }
    }
    worst
}

/// Bit pattern of the lower-triangle factor — the determinism currency.
fn factor_bits(tiles: &TileMatrix, n: usize) -> Vec<u64> {
    let l = tiles.to_dense(true);
    let mut bits = Vec::with_capacity(n * (n + 1) / 2);
    for j in 0..n {
        for i in j..n {
            bits.push(l.get(i, j).to_bits());
        }
    }
    bits
}

/// `A = M M^T / n + eps I` with a rank-`n/2` factor `M`: smallest
/// eigenvalue exactly `eps`, so loose truncation can push the matrix
/// indefinite on demand (same construction as the fault-injection suite).
fn spd_tiles(n: usize, nb: usize, seed: u64, eps: f64) -> TileMatrix {
    let r = n / 2;
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let m: Vec<f64> = (0..n * r).map(|_| rng.standard_normal()).collect();
    let mut a = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = 0.0;
            for k in 0..r {
                s += m[i * r + k] * m[j * r + k];
            }
            s /= n as f64;
            a[i * n + j] = s;
            a[j * n + i] = s;
        }
        a[i * n + i] += eps;
    }
    let dense = DenseMatrix::from_vec(n, a).unwrap();
    TileMatrix::from_dense(&dense, nb).unwrap()
}

/// Every rank-aware kernel against its dense oracle, each bounded by the
/// documented truncation-derived backward error: the kernels are exact in
/// the factors, so the only divergence from the dense result is the
/// `tol * ||operand||_F` compression error, amplified by the norms of the
/// other factors.
#[test]
fn rank_aware_kernels_stay_within_the_truncation_bound_of_the_dense_oracle() {
    let nb = 32usize;
    let tol = 1e-5;
    let tiles = matern_tiles(&locs_2d(3 * nb, 11), MaternParams::new(1.0, 0.1, 0.5), nb);
    let mut scratch = Vec::new();
    let a = tiles.tile(TileId::new(1, 0)).f64_values(&mut scratch).to_vec();
    let b = tiles.tile(TileId::new(2, 0)).f64_values(&mut scratch).to_vec();
    let c0 = tiles.tile(TileId::new(2, 1)).f64_values(&mut scratch).to_vec();
    let (ua, va, ra) = lowrank::compress(&a, nb, tol, nb).expect("full budget always compresses");
    let (ub, vb, rb) = lowrank::compress(&b, nb, tol, nb).expect("full budget always compresses");
    let (na, nbf) = (frob(&a), frob(&b));
    let be = NativeBackend;

    // gemm_lr_lr: both operands truncated
    let mut oracle = c0.clone();
    be.gemm_f64(&mut oracle, &a, &b, nb);
    let mut got = c0.clone();
    lowrank::gemm_lr_lr(&mut got, &ua, &va, ra, &ub, &vb, rb, nb);
    let bound = 3.0 * tol * na * nbf + 1e-12;
    let diff = frob_diff(&got, &oracle);
    assert!(diff <= bound, "gemm_lr_lr drifted {diff:.3e} > bound {bound:.3e}");

    // gemm_d_lr: only the right operand truncated
    let mut oracle = c0.clone();
    be.gemm_f64(&mut oracle, &a, &b, nb);
    let mut got = c0.clone();
    lowrank::gemm_d_lr(&mut got, &a, &ub, &vb, rb, nb);
    let bound = 2.0 * tol * na * nbf + 1e-12;
    let diff = frob_diff(&got, &oracle);
    assert!(diff <= bound, "gemm_d_lr drifted {diff:.3e} > bound {bound:.3e}");

    // gemm_lr_d: only the left operand truncated
    let mut oracle = c0.clone();
    be.gemm_f64(&mut oracle, &a, &b, nb);
    let mut got = c0.clone();
    lowrank::gemm_lr_d(&mut got, &ua, &va, ra, &b, nb);
    let bound = 2.0 * tol * na * nbf + 1e-12;
    let diff = frob_diff(&got, &oracle);
    assert!(diff <= bound, "gemm_lr_d drifted {diff:.3e} > bound {bound:.3e}");

    // syrk_lr: the truncated operand enters twice
    let mut oracle = c0.clone();
    be.syrk_f64(&mut oracle, &a, nb);
    let mut got = c0.clone();
    lowrank::syrk_lr(&mut got, &ua, &va, ra, nb);
    let bound = 3.0 * tol * na * na + 1e-12;
    let diff = frob_diff_lower(&got, &oracle, nb);
    assert!(diff <= bound, "syrk_lr drifted {diff:.3e} > bound {bound:.3e}");

    // trsm_lr: B~ L^-T vs B L^-T, amplified by ||L^-T||_F
    let mut l = tiles.tile(TileId::new(0, 0)).f64_values(&mut scratch).to_vec();
    be.potrf_f64(&mut l, nb, 0).expect("diagonal Matern tile is SPD");
    let mut linv_t = vec![0.0f64; nb * nb];
    for k in 0..nb {
        linv_t[k + k * nb] = 1.0;
    }
    be.trsm_f64(&l, &mut linv_t, nb);
    let mut oracle = b.clone();
    be.trsm_f64(&l, &mut oracle, nb);
    let mut vb2 = vb.clone();
    lowrank::trsm_lr(&l, &mut vb2, rb, nb);
    let mut got = vec![0.0f64; nb * nb];
    lowrank::decompress(&ub, &vb2, rb, nb, &mut got);
    let bound = 2.0 * tol * nbf * frob(&linv_t) + 1e-12;
    let diff = frob_diff(&got, &oracle);
    assert!(diff <= bound, "trsm_lr drifted {diff:.3e} > bound {bound:.3e}");
}

/// Satellite 3a: the truncation bound holds on real covariance tiles
/// across ranges, smoothnesses, and tolerances.
#[test]
fn truncation_error_bounded_across_matern_theta_sweep() {
    let nb = 32usize;
    let p = 4usize;
    for &range in &[0.02, 0.1, 0.3] {
        for &nu in &[0.5, 1.5, 2.5] {
            let tiles = matern_tiles(&locs_2d(p * nb, 7), MaternParams::new(1.0, range, nu), nb);
            let mut scratch = Vec::new();
            for i in 0..p {
                for j in 0..i {
                    let a = tiles.tile(TileId::new(i, j)).f64_values(&mut scratch).to_vec();
                    let na = frob(&a);
                    for &tol in &[1e-2, 1e-4, 1e-8] {
                        let (u, v, r) = lowrank::compress(&a, nb, tol, nb)
                            .expect("full budget always compresses");
                        let mut rec = vec![0.0f64; nb * nb];
                        lowrank::decompress(&u, &v, r, nb, &mut rec);
                        let err = frob_diff(&rec, &a);
                        assert!(
                            err <= tol * na * 1.000001 + 1e-12,
                            "range={range} nu={nu} tile=({i},{j}) tol={tol}: \
                             ||A - UV^T|| = {err:.3e} > {:.3e}",
                            tol * na
                        );
                    }
                }
            }
        }
    }
}

/// Satellite 3b: loosening the tolerance can only shrink the rank.
#[test]
fn rank_is_monotone_nonincreasing_in_tolerance() {
    let nb = 32usize;
    let p = 4usize;
    let tiles = matern_tiles(&locs_2d(p * nb, 13), MaternParams::new(1.0, 0.1, 0.5), nb);
    let mut scratch = Vec::new();
    // tight -> loose: each rank must be <= its predecessor's
    let tols = [1e-12, 1e-8, 1e-6, 1e-4, 1e-2, 1e-1];
    for i in 0..p {
        for j in 0..i {
            let a = tiles.tile(TileId::new(i, j)).f64_values(&mut scratch).to_vec();
            let mut prev = usize::MAX;
            for &tol in &tols {
                let (_, _, r) =
                    lowrank::compress(&a, nb, tol, nb).expect("full budget always compresses");
                assert!(
                    r <= prev,
                    "tile=({i},{j}): rank grew from {prev} to {r} as tol loosened to {tol}"
                );
                prev = r;
            }
        }
    }
}

/// Satellite 3c: with `tol = 0` and a full budget, compress falls back to
/// the exact `U = A, V = I` splitting and the roundtrip is bit-faithful.
#[test]
fn full_rank_budget_roundtrips_bitwise() {
    let nb = 32usize;
    let tiles = matern_tiles(&locs_2d(2 * nb, 17), MaternParams::new(1.0, 0.1, 0.5), nb);
    let mut scratch = Vec::new();
    let a = tiles.tile(TileId::new(1, 0)).f64_values(&mut scratch).to_vec();
    let (u, v, r) = lowrank::compress(&a, nb, 0.0, nb).expect("full budget always compresses");
    assert_eq!(r, nb, "tol=0 must exhaust the budget into the exact splitting");
    let mut rec = vec![0.0f64; nb * nb];
    lowrank::decompress(&u, &v, r, nb, &mut rec);
    for (k, (got, want)) in rec.iter().zip(a.iter()).enumerate() {
        assert_eq!(got.to_bits(), want.to_bits(), "roundtrip differs at flat index {k}");
    }
}

/// The tentpole byte pin: on a band-dominant map (collinear exponential
/// sites — every off-diagonal tile is numerically rank 1) the compressed
/// factor must be strictly cheaper than storing those same tiles as bf16,
/// i.e. the LowRank tier earns its place *below* the 2-byte formats.
#[test]
fn compressed_factor_beats_the_all_bf16_byte_floor_on_band_dominant_maps() {
    let (n, nb) = (512usize, 64usize);
    let p = n / nb;
    let theta = MaternParams::new(1.0, 0.05, 0.5);
    let variant = Variant::Tlr { tolerance: 1e-3, max_rank: 16 };
    let locs = locs_1d(n);
    let sched = Scheduler::with_workers(4);
    let mut tiles = TileMatrix::zeros(n, nb).unwrap();
    generate_covariance(&mut tiles, &locs, theta, Metric::Euclidean, 1e-8, &NativeBackend, &sched)
        .unwrap();
    factorize_tiles(&mut tiles, variant, &NativeBackend, &sched).unwrap();
    let stats = tiles.tlr_stats();
    assert!(stats.tiles >= p, "band-dominant map should compress many tiles, got {}", stats.tiles);
    assert!(stats.avg_rank() <= 4.0, "collinear exponential tiles are rank ~1: {stats:?}");
    // compressed tiles vs the same tiles stored bf16 (2 bytes/value)
    let bf16_floor = stats.tiles * nb * nb * 2;
    assert!(
        stats.bytes < bf16_floor,
        "compressed bytes {} must beat the bf16 floor {bf16_floor}",
        stats.bytes
    );
    // whole lower triangle vs an f64-diagonal/bf16-everywhere-else ladder
    let map_floor = p * nb * nb * 8 + (p * (p - 1) / 2) * nb * nb * 2;
    let resident = tiles.resident_bytes();
    assert!(resident < map_floor, "resident {resident} must beat the all-bf16 floor {map_floor}");
}

/// TLR factorization must be bit-deterministic across worker counts and
/// all four ready-queue policies: every compressed-tile mutation happens
/// inside a single task with a fixed internal order, so the schedule
/// cannot leak into the factors.
#[test]
fn tlr_factorization_is_deterministic_across_workers_and_policies() {
    let (n, nb) = (256usize, 32usize);
    let theta = MaternParams::new(1.0, 0.05, 0.5);
    let variant = Variant::Tlr { tolerance: 1e-3, max_rank: 32 };
    let locs = locs_1d(n);
    let mut reference: Option<Vec<u64>> = None;
    for workers in [1usize, 4, 8] {
        for policy in [
            SchedulingPolicy::Fifo,
            SchedulingPolicy::Lifo,
            SchedulingPolicy::CriticalPath,
            SchedulingPolicy::PrecisionFrontier,
        ] {
            let sched = Scheduler::new(SchedulerConfig {
                num_workers: workers,
                policy,
                ..Default::default()
            });
            let mut tiles = TileMatrix::zeros(n, nb).unwrap();
            generate_covariance(
                &mut tiles,
                &locs,
                theta,
                Metric::Euclidean,
                1e-8,
                &NativeBackend,
                &sched,
            )
            .unwrap();
            factorize_tiles(&mut tiles, variant, &NativeBackend, &sched).unwrap();
            assert!(
                tiles.tlr_stats().tiles > 0,
                "determinism pin is vacuous without compressed tiles"
            );
            let bits = factor_bits(&tiles, n);
            match &reference {
                None => reference = Some(bits),
                Some(want) => assert_eq!(
                    want, &bits,
                    "workers={workers} policy={policy:?}: TLR factor must be bit-identical"
                ),
            }
        }
    }
}

/// Accuracy: under a hostile marker map that compresses *every*
/// off-diagonal tile, the reconstruction error tracks the tolerance
/// (bounded by a generous tol-derived constant), while the paper's
/// independent-block approximation — which zeroes those same blocks — is
/// qualitatively worse at the same block size.
#[test]
fn tlr_reconstruction_tracks_tolerance_and_beats_independent_blocks() {
    let (n, nb) = (256usize, 64usize);
    let p = n / nb;
    let locs = locs_2d(n, 33);
    let theta = MaternParams::new(1.0, 0.1, 0.5);
    let vals = matern_matrix(&locs, &theta, Metric::Euclidean, 1e-8);
    let a_frob = frob(&vals);
    let a = DenseMatrix::from_vec(n, vals).unwrap();
    let sched = Scheduler::with_workers(4);
    let tol = 1e-6;

    let marker = PrecisionMap::from_fn(
        p,
        |i, j| if i == j { Precision::F64 } else { Precision::F16 },
    );
    let mut tlr_tiles = TileMatrix::from_dense(&a, nb).unwrap();
    factorize_tiles_with_map(
        &mut tlr_tiles,
        Variant::Tlr { tolerance: tol, max_rank: nb },
        marker,
        &NativeBackend,
        &sched,
    )
    .expect("tol-bounded truncation must keep the matrix positive definite");
    assert_eq!(tlr_tiles.tlr_stats().tiles, p * (p - 1) / 2, "every off-diag tile compressed");
    let err_tlr = reconstruction_err(&tlr_tiles, &a, n);
    let bound = 50.0 * (p * p) as f64 * tol * a_frob;
    assert!(err_tlr <= bound, "TLR backward error {err_tlr:.3e} exceeds bound {bound:.3e}");

    // dense DP reference: TLR cannot be *more* accurate than roundoff
    let mut dp_tiles = TileMatrix::from_dense(&a, nb).unwrap();
    factorize_tiles(&mut dp_tiles, Variant::FullDp, &NativeBackend, &sched).unwrap();
    let err_dp = reconstruction_err(&dp_tiles, &a, n);
    assert!(err_dp <= err_tlr.max(1e-10), "DP reference drifted: {err_dp:.3e}");

    // the independent-blocks baseline drops those blocks entirely
    let mut ib_tiles = TileMatrix::from_dense(&a, nb).unwrap();
    factorize_tiles(&mut ib_tiles, Variant::IndependentBlocks, &NativeBackend, &sched).unwrap();
    let err_ib = reconstruction_err(&ib_tiles, &a, n);
    assert!(
        err_ib > 1e-2 && err_ib > 20.0 * err_tlr.max(1e-12),
        "independent blocks should be qualitatively worse: ib={err_ib:.3e} tlr={err_tlr:.3e}"
    );
}

/// Satellite 2: a breakdown inside a compressed panel climbs the
/// escalation ladder (LowRank -> f32 -> f64 via the F16 marker), and the
/// rescued factor is bit-identical to factoring under the escalated map
/// directly — compression is deterministic, and each retry restarts from
/// the same pristine f64 snapshot.
#[test]
fn recovery_ladder_rescues_a_compressed_panel_breakdown_bit_identically() {
    let (nb, p) = (32usize, 3usize);
    let n = nb * p;
    // tol 0.5 truncates random (full-rank) Wishart tiles brutally: the
    // compressed panel's perturbation dwarfs eps and breaks definiteness
    let variant = Variant::Tlr { tolerance: 0.5, max_rank: nb };
    let hostile = PrecisionMap::from_fn(
        p,
        |i, j| if i == j { Precision::F64 } else { Precision::F16 },
    );
    let sched = Scheduler::with_workers(2);

    let mut broken = None;
    'search: for seed in 1..10 {
        for eps in [1e-3, 1e-6, 1e-9] {
            let mut tiles = spd_tiles(n, nb, seed, eps);
            let r = factorize_tiles_with_map(
                &mut tiles,
                variant,
                hostile.clone(),
                &NativeBackend,
                &sched,
            );
            match r {
                Err(Error::NotPositiveDefinite { .. }) => {
                    broken = Some((seed, eps));
                    break 'search;
                }
                Ok(_) => {}
                Err(e) => panic!("unexpected failure probing seed={seed} eps={eps}: {e}"),
            }
        }
    }
    let (seed, eps) = broken.expect("no (seed, eps) in the grid broke the compressed panel");

    let mut tiles = spd_tiles(n, nb, seed, eps);
    let (plan, trace) = factorize_tiles_with_recovery(
        &mut tiles,
        variant,
        hostile.clone(),
        PlanOptions::default(),
        RecoveryOptions { max_retries: 12 },
        &NativeBackend,
        &sched,
    )
    .expect("escalation ladder failed to rescue the compressed breakdown");
    assert!(trace.attempts >= 1, "recovery must have retried");
    assert!(trace.escalated_tiles >= 1, "recovery must have promoted compressed tiles");
    assert_eq!(trace.map_churn, hostile.churn(&plan.map));
    // the ladder's first rung off LowRank is dense f32: the rescued map
    // must hold at least one tile the marker wanted compressed at f32+
    let promoted = (0..p)
        .flat_map(|i| (0..i).map(move |j| (i, j)))
        .filter(|&(i, j)| matches!(plan.map.get(i, j), Precision::F32 | Precision::F64))
        .count();
    assert!(promoted >= 1, "no compressed tile climbed to dense f32/f64: {:?}", plan.map);

    let mut direct = spd_tiles(n, nb, seed, eps);
    factorize_tiles_with_map(&mut direct, variant, plan.map.clone(), &NativeBackend, &sched)
        .expect("the escalated map must factor directly");
    assert_eq!(
        factor_bits(&tiles, n),
        factor_bits(&direct, n),
        "rescued factor differs from the direct escalated-map run"
    );
}

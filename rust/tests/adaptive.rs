//! Acceptance test for `Variant::Adaptive` at the issue's reference
//! setup: a 1024-site Morton-ordered synthetic field, nb = 128 (p = 8).
//!
//! Asserts the three acceptance criteria:
//! 1. adaptive at tolerance 1e-8 assigns strictly fewer F64 tiles than
//!    full DP;
//! 2. its planner reports a lower dp-flop share than
//!    `MixedPrecision { diag_thick: p }` (the all-DP band);
//! 3. the factorization's forward error — measured end to end, as the
//!    held-out prediction error of the kriging pipeline built on the
//!    factor — stays within 10x of the full-DP result.  The raw backward
//!    error of the factor is additionally checked to track the requested
//!    tolerance.

use mpcholesky::matern::matern_matrix;
use mpcholesky::prelude::*;
use mpcholesky::tile::DenseMatrix;

#[test]
fn adaptive_1024_census_flops_and_forward_error() {
    let n = 1024;
    let nb = 128;
    let p = n / nb;
    let tol = 1e-8;

    // Morton-ordered synthetic field (SyntheticField sorts internally)
    let field = SyntheticField::generate(&FieldConfig {
        n,
        theta: MaternParams::new(1.0, 0.1, 0.5),
        seed: 42,
        gen_nb: nb,
        ..Default::default()
    })
    .unwrap();
    let a = DenseMatrix::from_vec(
        n,
        matern_matrix(&field.locations, &field.theta, Metric::Euclidean, 1e-8),
    )
    .unwrap();
    let sched = Scheduler::with_workers(4);

    let mut t_dp = TileMatrix::from_dense(&a, nb).unwrap();
    let plan_dp = factorize_tiles(&mut t_dp, Variant::FullDp, &NativeBackend, &sched).unwrap();

    let mut t_ad = TileMatrix::from_dense(&a, nb).unwrap();
    let plan_ad = factorize_tiles(
        &mut t_ad,
        Variant::Adaptive { tolerance: tol },
        &NativeBackend,
        &sched,
    )
    .unwrap();

    // 1. strictly fewer F64 tiles than full DP
    let total = p * (p + 1) / 2;
    assert_eq!(plan_dp.census().dp, total);
    let census = plan_ad.census();
    assert_eq!(census.total(), total);
    assert!(
        census.dp < total,
        "adaptive tol={tol} demoted nothing: {census:?} ({})",
        plan_ad.map.label()
    );

    // 2. lower dp-flop share than the all-DP band MixedPrecision{p}
    let band = CholeskyPlan::build(p, nb, Variant::MixedPrecision { diag_thick: p }, false);
    assert!(
        plan_ad.dp_flop_fraction() < band.dp_flop_fraction(),
        "adaptive dp-flop share {} !< band share {}",
        plan_ad.dp_flop_fraction(),
        band.dp_flop_fraction()
    );

    // 3a. the factor's backward error tracks the tolerance
    let l = t_ad.to_dense(true);
    let llt = l.matmul_nt(&l);
    let mut err = 0.0f64;
    for j in 0..n {
        for i in j..n {
            err = err.max((llt.get(i, j) - a.get(i, j)).abs());
        }
    }
    assert!(err < 1e-6, "||LL^T - A||_max = {err} does not track tolerance {tol}");

    // 3b. end-to-end forward error: krige 256 held-out sites from the 768
    // others (768 = 6 tiles) with each variant's factorization
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    rng.shuffle(&mut idx);
    let (test_idx, train_idx) = idx.split_at(256);
    let pick = |ids: &[usize]| -> (Vec<Location>, Vec<f64>) {
        (
            ids.iter().map(|&i| field.locations[i]).collect(),
            ids.iter().map(|&i| field.values[i]).collect(),
        )
    };
    let (te_locs, te_z) = pick(test_idx);
    let (tr_locs, tr_z) = pick(train_idx);
    let forward_err = |variant: Variant| -> f64 {
        let cfg = MleConfig { nb, variant, ..Default::default() };
        let model = KrigingModel::fit(&tr_locs, &tr_z, field.theta, &cfg).unwrap();
        pmse(&model.predict(&te_locs), &te_z)
    };
    let e_dp = forward_err(Variant::FullDp);
    let e_ad = forward_err(Variant::Adaptive { tolerance: tol });
    assert!(
        e_ad <= 10.0 * e_dp,
        "adaptive forward (prediction) error {e_ad} not within 10x of full DP {e_dp}"
    );
}

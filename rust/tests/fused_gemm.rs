//! GemmBatch fusion acceptance: fused (left-looking, one task per
//! output tile) plans must produce **bit-identical** final tiles to
//! unfused (right-looking, one task per rank-nb update) plans wherever
//! the target storage does not round between updates — DP enforced
//! bitwise per the issue, and f32 targets get the same guarantee for
//! free — under every scheduler policy.  bf16 targets round through
//! storage once per batch instead of once per step, so the
//! three-precision comparison is tolerance-based.
//!
//! Plus the per-step bf16 decode-cache acceptance: the run's unpack
//! count must drop *strictly below* the per-task-unpack baseline (what
//! the pre-decode-cache executor paid: one unpack per reduced-consumer
//! read of a packed tile, plus one per bf16 in-place compute target).

use mpcholesky::cholesky::{
    factorize_tiles_with_opts, CholeskyPlan, GenContext, KernelCall, TileExecutor,
};
use mpcholesky::matern::matern_matrix;
use mpcholesky::prelude::*;
use mpcholesky::tile::{DenseMatrix, Precision};

fn matern_dense(n: usize, seed: u64) -> DenseMatrix {
    let theta = MaternParams::new(1.0, 0.1, 0.5);
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut locs: Vec<Location> = (0..n)
        .map(|_| Location::new(r.uniform_open(0.0, 1.0), r.uniform_open(0.0, 1.0)))
        .collect();
    locs.sort_by(|a, b| (a.x + a.y).partial_cmp(&(b.x + b.y)).unwrap());
    DenseMatrix::from_vec(n, matern_matrix(&locs, &theta, Metric::Euclidean, 1e-8)).unwrap()
}

/// Factor `a` through the public driver and return the dense factor.
fn factor(
    a: &DenseMatrix,
    nb: usize,
    variant: Variant,
    fused: bool,
    policy: SchedulingPolicy,
) -> DenseMatrix {
    let sched = Scheduler::new(SchedulerConfig { num_workers: 4, policy, trace: false });
    let mut tiles = TileMatrix::from_dense(a, nb).unwrap();
    let map = variant.precision_map(tiles.p(), Some(&tiles)).unwrap();
    factorize_tiles_with_opts(
        &mut tiles,
        variant,
        map,
        PlanOptions { fuse_gemm: fused },
        &NativeBackend,
        &sched,
    )
    .unwrap();
    tiles.to_dense(true)
}

#[test]
fn fused_dp_bit_identical_to_unfused_under_all_policies() {
    let a = matern_dense(160, 31);
    let reference = factor(&a, 32, Variant::FullDp, false, SchedulingPolicy::Fifo);
    for policy in [
        SchedulingPolicy::Fifo,
        SchedulingPolicy::Lifo,
        SchedulingPolicy::CriticalPath,
        SchedulingPolicy::PrecisionFrontier,
    ] {
        let fused = factor(&a, 32, Variant::FullDp, true, policy);
        assert_eq!(
            fused.max_abs_diff(&reference),
            0.0,
            "{policy:?}: fused DP factor diverges from unfused"
        );
    }
}

#[test]
fn fused_mixed_precision_bit_identical_to_unfused() {
    // f32 targets accumulate in their resident buffer in both schemes,
    // in the same ascending-k order, with identically-converted
    // operands — so even the mixed variant matches bitwise
    let a = matern_dense(160, 32);
    let variant = Variant::MixedPrecision { diag_thick: 2 };
    let unfused = factor(&a, 32, variant, false, SchedulingPolicy::PrecisionFrontier);
    for policy in [SchedulingPolicy::Fifo, SchedulingPolicy::CriticalPath] {
        let fused = factor(&a, 32, variant, true, policy);
        assert_eq!(
            fused.max_abs_diff(&unfused),
            0.0,
            "{policy:?}: fused mixed factor diverges from unfused"
        );
    }
}

#[test]
fn fused_three_precision_reconstructs_like_unfused() {
    // bf16 targets round through storage once per batch instead of once
    // per step: not bitwise, but both factors must reconstruct A to the
    // same bf16-level accuracy
    let n = 160;
    let a = matern_dense(n, 33);
    let variant = Variant::ThreePrecision { dp_thick: 1, sp_thick: 3 };
    let unfused = factor(&a, 32, variant, false, SchedulingPolicy::Fifo);
    let fused = factor(&a, 32, variant, true, SchedulingPolicy::Fifo);
    for l in [&unfused, &fused] {
        let llt = l.matmul_nt(l);
        let mut err = 0.0f64;
        for j in 0..n {
            for i in j..n {
                err = err.max((llt.get(i, j) - a.get(i, j)).abs());
            }
        }
        assert!(err < 0.1, "3-precision reconstruction err {err}");
    }
    // and the two factors differ only at bf16 storage-rounding scale
    assert!(
        fused.max_abs_diff(&unfused) < 0.1,
        "fused vs unfused 3p diff {}",
        fused.max_abs_diff(&unfused)
    );
}

/// What the pre-decode-cache executor would unpack for this plan: one
/// unpack per reduced-consumer read of a packed-bf16 tile, one per
/// packed in-place compute target, and one per `sconv2d` of a packed
/// tile (identical in both worlds).
fn per_task_unpack_baseline(plan: &CholeskyPlan) -> u64 {
    let map = &plan.map;
    let is_hp = |i: usize, j: usize| map.get(i, j) == Precision::Bf16;
    let mut count = 0u64;
    for t in plan.graph.tasks() {
        match t.payload.call {
            KernelCall::PotrfDp { k } => {
                if is_hp(k, k) {
                    count += 1;
                }
            }
            KernelCall::TrsmSp { k, .. } => {
                if is_hp(k, k) {
                    count += 1;
                }
            }
            KernelCall::TrsmHp { k, .. } => {
                count += 1; // in-place bf16 solve target
                if is_hp(k, k) {
                    count += 1;
                }
            }
            KernelCall::SyrkDp { j, k } => match map.get(j, j) {
                Precision::F64 => {}
                // this baseline models bf16-only maps (diagonals are
                // never F16 in the plans exercised here)
                Precision::F32 | Precision::F16 => {
                    if is_hp(j, k) {
                        count += 1;
                    }
                }
                Precision::Bf16 => {
                    count += 1; // in-place bf16 accumulate target
                    if is_hp(j, k) {
                        count += 1;
                    }
                }
            },
            KernelCall::GemmSp { i, j: _, k } => {
                // reduced compute: both operands unpack when packed
                if is_hp(i, k) {
                    count += 1;
                }
            }
            KernelCall::GemmHp { i, j: _, k } => {
                count += 1; // C unpack
                if is_hp(i, k) {
                    count += 1;
                }
            }
            KernelCall::PromoteTile { i, k } => {
                if is_hp(i, k) {
                    count += 1;
                }
            }
            _ => {}
        }
        // second gemm operand (j, k) — shared handling for both kinds
        match t.payload.call {
            KernelCall::GemmSp { j, k, .. } | KernelCall::GemmHp { j, k, .. } => {
                if is_hp(j, k) {
                    count += 1;
                }
            }
            _ => {}
        }
    }
    count
}

#[test]
fn decode_cache_strictly_reduces_unpacks_below_per_task_baseline() {
    let n = 256;
    let nb = 32;
    let a = matern_dense(n, 34);
    let variant = Variant::ThreePrecision { dp_thick: 1, sp_thick: 3 };
    let sched = Scheduler::with_workers(4);

    let mut tiles = TileMatrix::from_dense(&a, nb).unwrap();
    let map = variant.precision_map(tiles.p(), Some(&tiles)).unwrap();
    assert!(map.census().hp > 0, "setup must assign bf16 tiles");
    tiles.apply_precision_map(&map);
    let mut plan =
        CholeskyPlan::build_with_opts(tiles.p(), nb, variant, map, false, PlanOptions::default());
    let baseline = per_task_unpack_baseline(&plan);
    assert!(baseline > 0);

    let accesses: Vec<_> = plan.graph.tasks().iter().map(|t| t.accesses.clone()).collect();
    let exec = TileExecutor::new(&tiles, &NativeBackend);
    sched.run(&mut plan.graph, |idx, sc| exec.execute(sc, &accesses[idx])).unwrap();

    let actual = exec.stats.bf16_unpacks();
    assert!(actual > 0);
    assert!(
        actual < baseline,
        "decode cache must strictly beat per-task unpacking: {actual} !< {baseline}"
    );
    assert!(exec.stats.decode_ns() > 0, "timed unpacks must accumulate");
}

#[test]
fn fused_plans_execute_on_the_scheduler_with_generation() {
    // generation tasks, batches, trsms and conversions in one dataflow
    // graph: the end-to-end fused pipeline must equal the dense path
    let n = 128;
    let nb = 32;
    let theta = MaternParams::new(1.0, 0.1, 0.5);
    let mut r = Xoshiro256pp::seed_from_u64(77);
    let mut locs: Vec<Location> = (0..n)
        .map(|_| Location::new(r.uniform_open(0.0, 1.0), r.uniform_open(0.0, 1.0)))
        .collect();
    locs.sort_by(|a, b| (a.x + a.y).partial_cmp(&(b.x + b.y)).unwrap());
    let a =
        DenseMatrix::from_vec(n, matern_matrix(&locs, &theta, Metric::Euclidean, 1e-8)).unwrap();

    let variant = Variant::MixedPrecision { diag_thick: 2 };
    let sched = Scheduler::with_workers(4);

    // fused generate+factorize in one graph
    let mut tiles = TileMatrix::zeros(n, nb).unwrap();
    let map = variant.precision_map(n / nb, None).unwrap();
    tiles.apply_precision_map(&map);
    let mut plan = CholeskyPlan::build_fused(n / nb, nb, variant, map, true);
    let accesses: Vec<_> = plan.graph.tasks().iter().map(|t| t.accesses.clone()).collect();
    let exec = TileExecutor::new(&tiles, &NativeBackend).with_generation(GenContext {
        locations: &locs,
        theta,
        metric: Metric::Euclidean,
        nugget: 1e-8,
    });
    sched.run(&mut plan.graph, |idx, sc| exec.execute(sc, &accesses[idx])).unwrap();

    let dense_path = factor(&a, nb, variant, true, SchedulingPolicy::PrecisionFrontier);
    assert_eq!(tiles.to_dense(true).max_abs_diff(&dense_path), 0.0);
}

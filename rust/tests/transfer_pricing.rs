//! Per-tile transfer pricing on the realized `PrecisionMap` — the
//! acceptance tests for threading the map through the Fig. 5 (device)
//! and Fig. 6 (network) models.
//!
//! The load-bearing claim: replaying the *same* plan graph under an
//! adaptive map prices strictly fewer transferred bytes than under an
//! all-f64 map, and the delta is exactly the map's per-tile byte
//! savings — the volume effect the paper's speedups come from.  Plus
//! property tests for the LRU device model: monotone transfer volume in
//! device memory, `prefetch_overfetch = 1.0` charging demand misses
//! only, and an all-f64 map reproducing the DP(100%) volume exactly.

use mpcholesky::matern::matern_matrix;
use mpcholesky::prelude::*;
use mpcholesky::scheduler::datamove::{self, DeviceModel};
use mpcholesky::scheduler::distributed::{self, ClusterModel};
use mpcholesky::scheduler::{Access, TaskCost, TaskGraph};
use mpcholesky::tile::{DenseMatrix, TileId};

/// The adaptive.rs reference setup: 1024 Morton-ordered sites, nb = 128
/// (p = 8), tolerance 1e-8 — a map known to demote off-diagonal tiles.
fn adaptive_setup() -> (usize, usize, PrecisionMap, CholeskyPlan) {
    let n = 1024;
    let nb = 128;
    let p = n / nb;
    let tol = 1e-8;
    let field = SyntheticField::generate(&FieldConfig {
        n,
        theta: MaternParams::new(1.0, 0.1, 0.5),
        seed: 42,
        gen_nb: nb,
        ..Default::default()
    })
    .unwrap();
    let a = DenseMatrix::from_vec(
        n,
        matern_matrix(&field.locations, &field.theta, Metric::Euclidean, 1e-8),
    )
    .unwrap();
    let tiles = TileMatrix::from_dense(&a, nb).unwrap();
    let map = PrecisionMap::adaptive(&tiles, tol);
    assert!(
        map.census().dp < p * (p + 1) / 2,
        "setup must demote something: {}",
        map.label()
    );
    let variant = Variant::Adaptive { tolerance: tol };
    let plan = CholeskyPlan::build_with_map(p, nb, variant, map.clone(), true);
    (p, nb, map, plan)
}

/// Device with memory far beyond the working set and no overfetch: every
/// distinct tile is loaded exactly once and nothing is ever evicted, so
/// the demand volume is exactly the sum of stored tile bytes.
fn ample_device() -> DeviceModel {
    let mut dev = DeviceModel::v100();
    dev.prefetch_overfetch = 1.0;
    dev
}

#[test]
fn datamove_adaptive_map_saves_exactly_the_per_tile_bytes() {
    let (p, nb, map, plan) = adaptive_setup();
    let dev = ample_device();
    let dp_map = PrecisionMap::uniform(p, Precision::F64);

    let rep_ad = datamove::simulate(&plan.graph, &dev, nb, &map);
    let rep_dp = datamove::simulate(&plan.graph, &dev, nb, &dp_map);

    // same plan, same misses — only the priced bytes differ
    assert_eq!(rep_ad.transfers, rep_dp.transfers);
    assert!(
        rep_ad.demand_bytes < rep_dp.demand_bytes,
        "adaptive map must move strictly fewer bytes: {} !< {}",
        rep_ad.demand_bytes,
        rep_dp.demand_bytes
    );
    // the delta is exactly the map's storage saving over the triangle
    let expected = (dp_map.storage_bytes(nb) - map.storage_bytes(nb)) as f64;
    assert!(expected > 0.0);
    assert_eq!(rep_dp.demand_bytes - rep_ad.demand_bytes, expected);
}

#[test]
fn distributed_adaptive_map_saves_exactly_the_per_message_bytes() {
    let (p, nb, map, plan) = adaptive_setup();
    let cluster = ClusterModel::shaheen(4);
    let dp_map = PrecisionMap::uniform(p, Precision::F64);

    let rep_ad = distributed::simulate(&plan.graph, &cluster, nb, &map);
    let rep_dp = distributed::simulate(&plan.graph, &cluster, nb, &dp_map);

    // message counts are an ownership/DAG property, independent of the map
    assert_eq!(rep_ad.messages, rep_dp.messages);
    assert_eq!(rep_ad.per_tile_messages, rep_dp.per_tile_messages);
    assert!(rep_ad.messages > 0, "a p=8 plan on 4 nodes must communicate");

    let mut expected = 0.0f64;
    for (t, &m) in &rep_dp.per_tile_messages {
        let saved = 8 - map.get(t.i, t.j).bytes();
        expected += (m * saved * nb * nb) as f64;
    }
    assert!(
        expected > 0.0,
        "at least one demoted tile must cross the network ({})",
        map.label()
    );
    assert_eq!(rep_dp.total_comm_bytes - rep_ad.total_comm_bytes, expected);
    assert!(rep_ad.total_comm_bytes < rep_dp.total_comm_bytes);
}

#[test]
fn datamove_all_f64_map_reproduces_dp100_volume_exactly() {
    let nb = 128;
    let p = 8;
    let plan = CholeskyPlan::build(p, nb, Variant::FullDp, true);
    let dev = ample_device();
    let rep = datamove::simulate(&plan.graph, &dev, nb, &PrecisionMap::uniform(p, Precision::F64));
    let tiles = p * (p + 1) / 2;
    // each tile loads once, nothing evicts, nothing writes back
    assert_eq!(rep.transfers, tiles);
    assert_eq!(rep.demand_bytes, (tiles * nb * nb * 8) as f64);
    assert_eq!(rep.moved_bytes, rep.demand_bytes, "overfetch 1.0 = demand misses only");
}

struct ReadTask;
impl TaskCost for ReadTask {
    fn flops(&self) -> f64 {
        1.0
    }
    fn precision(&self) -> Precision {
        Precision::F64
    }
}

#[test]
fn datamove_transfer_bytes_monotone_in_device_memory() {
    // read-only pseudo-random reuse pattern over 12 tiles: LRU is a
    // stack algorithm, so misses (and with them transfer bytes) must be
    // non-increasing as device memory grows
    let nb = 64usize;
    let tile_bytes = nb * nb * 8;
    let mut g: TaskGraph<ReadTask> = TaskGraph::new();
    let mut state = 0xabcdef12345u64;
    for _ in 0..300 {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let t = ((state >> 33) as usize) % 12;
        g.submit(ReadTask, vec![(TileId::new(t, t), Access::Read)]);
    }
    let map = PrecisionMap::uniform(12, Precision::F64);
    let mut prev = f64::INFINITY;
    for tiles_cap in 1..=13usize {
        let mut dev = DeviceModel::v100();
        dev.prefetch_overfetch = 1.0;
        dev.gpu_mem_bytes = tiles_cap * tile_bytes;
        let rep = datamove::simulate(&g, &dev, nb, &map);
        assert!(
            rep.demand_bytes <= prev,
            "demand grew with memory: cap={tiles_cap} tiles, {} > {prev}",
            rep.demand_bytes
        );
        prev = rep.demand_bytes;
    }
}

#[test]
fn datamove_plan_replay_monotone_between_extreme_capacities() {
    // on a real mixed plan: ample memory is a lower bound (each tile
    // once), one-tile memory an upper bound (every touch misses)
    let nb = 64;
    let p = 8;
    let plan = CholeskyPlan::build(p, nb, Variant::MixedPrecision { diag_thick: 2 }, true);
    let ample = ample_device();
    let mut tiny = ample_device();
    tiny.gpu_mem_bytes = nb * nb * 8; // exactly one DP tile
    let big = datamove::simulate(&plan.graph, &ample, nb, &plan.map);
    let small = datamove::simulate(&plan.graph, &tiny, nb, &plan.map);
    assert!(
        big.demand_bytes <= small.demand_bytes,
        "{} !<= {}",
        big.demand_bytes,
        small.demand_bytes
    );
    assert!(big.transfers <= small.transfers);
}

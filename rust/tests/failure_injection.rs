//! Failure-injection integration tests: the system must fail *cleanly*
//! (typed errors, no partial-state corruption, optimizer recovery) under
//! the error modes the paper's SSVIII.D discusses and a few it doesn't.

use std::sync::atomic::{AtomicUsize, Ordering};

use mpcholesky::cholesky::{factorize_tiles, Variant};
use mpcholesky::error::Error;
use mpcholesky::kernels::{NativeBackend, TileBackend};
use mpcholesky::matern::{Location, MaternParams, Metric};
use mpcholesky::prelude::*;
use mpcholesky::scheduler::Scheduler;
use mpcholesky::tile::{DenseMatrix, TileMatrix};

/// Backend wrapper that fails the Nth potrf — simulates a numeric fault
/// deep inside a scheduled run.
struct FailingBackend {
    inner: NativeBackend,
    fail_at: usize,
    count: AtomicUsize,
}

impl TileBackend for FailingBackend {
    fn potrf_f64(&self, a: &mut [f64], nb: usize, row0: usize) -> mpcholesky::error::Result<()> {
        let k = self.count.fetch_add(1, Ordering::SeqCst);
        if k == self.fail_at {
            return Err(Error::NotPositiveDefinite { pivot: -1.0, index: row0 });
        }
        self.inner.potrf_f64(a, nb, row0)
    }
    fn potrf_f32(&self, a: &mut [f32], nb: usize, row0: usize) -> mpcholesky::error::Result<()> {
        self.inner.potrf_f32(a, nb, row0)
    }
    fn trsm_f64(&self, l: &[f64], b: &mut [f64], nb: usize) {
        self.inner.trsm_f64(l, b, nb)
    }
    fn trsm_f32(&self, l: &[f32], b: &mut [f32], nb: usize) {
        self.inner.trsm_f32(l, b, nb)
    }
    fn syrk_f64(&self, c: &mut [f64], a: &[f64], nb: usize) {
        self.inner.syrk_f64(c, a, nb)
    }
    fn syrk_f32(&self, c: &mut [f32], a: &[f32], nb: usize) {
        self.inner.syrk_f32(c, a, nb)
    }
    fn gemm_f64(&self, c: &mut [f64], a: &[f64], b: &[f64], nb: usize) {
        self.inner.gemm_f64(c, a, b, nb)
    }
    fn gemm_f32(&self, c: &mut [f32], a: &[f32], b: &[f32], nb: usize) {
        self.inner.gemm_f32(c, a, b, nb)
    }
    fn name(&self) -> &'static str {
        "failing"
    }
}

fn matern_tiles(n: usize, nb: usize, seed: u64) -> TileMatrix {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut locs: Vec<Location> = (0..n)
        .map(|_| Location::new(r.uniform_open(0.0, 1.0), r.uniform_open(0.0, 1.0)))
        .collect();
    mpcholesky::datagen::morton_sort(&mut locs);
    let a = DenseMatrix::from_vec(
        n,
        mpcholesky::matern::matern_matrix(
            &locs,
            &MaternParams::new(1.0, 0.05, 0.5),
            Metric::Euclidean,
            1e-8,
        ),
    )
    .unwrap();
    TileMatrix::from_dense(&a, nb).unwrap()
}

#[test]
fn mid_run_kernel_failure_propagates_typed_error() {
    for fail_at in [0, 1, 3] {
        let be = FailingBackend {
            inner: NativeBackend,
            fail_at,
            count: AtomicUsize::new(0),
        };
        let mut tiles = matern_tiles(256, 64, 1);
        let sched = Scheduler::with_workers(2);
        match factorize_tiles(&mut tiles, Variant::FullDp, &be, &sched) {
            Err(Error::NotPositiveDefinite { pivot, index }) => {
                assert_eq!(pivot, -1.0);
                assert_eq!(index, fail_at * 64, "failure reports the right tile");
            }
            other => panic!("fail_at={fail_at}: expected typed failure, got {other:?}"),
        }
    }
}

#[test]
fn failure_does_not_hang_wide_graphs() {
    // failure at the very first potrf of a large graph: every dependent
    // task must be drained without deadlock, quickly
    let be = FailingBackend { inner: NativeBackend, fail_at: 0, count: AtomicUsize::new(0) };
    let mut tiles = matern_tiles(1024, 64, 2);
    let sched = Scheduler::with_workers(4);
    let t0 = std::time::Instant::now();
    assert!(factorize_tiles(&mut tiles, Variant::MixedPrecision { diag_thick: 2 }, &be, &sched)
        .err()
        .is_some());
    assert!(t0.elapsed().as_secs_f64() < 5.0, "drain took {:?}", t0.elapsed());
}

#[test]
fn optimizer_recovers_from_rejected_regions() {
    // Bounds that include a region where the DST covariance loses PD:
    // the fit must still converge to a finite answer by rejecting those
    // evaluations (the paper's SP(100%)/DST failure handling).
    let f = SyntheticField::generate(&FieldConfig {
        n: 256,
        theta: MaternParams::new(1.0, 0.05, 0.5),
        seed: 3,
        ..Default::default()
    })
    .unwrap();
    let cfg = MleConfig {
        nb: 64,
        variant: Variant::Dst { diag_thick: 2 },
        // wide range bound: large ranges make the banded matrix non-PD
        lower: [0.1, 0.005, 0.3],
        upper: [10.0, 1.0, 1.0],
        start: Some([1.0, 0.02, 0.5]),
        optimizer: mpcholesky::mle::OptimizerConfig { max_evals: 60, ..Default::default() },
        ..Default::default()
    };
    let fit = MleProblem::new(&f.locations, &f.values, cfg).unwrap().fit().unwrap();
    assert!(fit.loglik.is_finite());
    assert!(fit.theta.range < 0.5, "optimizer should stay in the PD region: {:?}", fit.theta);
}

#[test]
fn sp100_equivalent_fails_as_paper_describes() {
    // The paper excludes SP(100%) because "the covariance matrix may lose
    // the numerical property of positive definiteness".  Our analog: a
    // strongly correlated matrix squeezed through bf16 far bands with a
    // *zero-width* DP band is at risk; with diag_thick >= 1 the potrf
    // chain stays DP and must succeed even when far bands are bf16.
    let mut tiles = matern_tiles(320, 64, 4);
    let sched = Scheduler::with_workers(2);
    let r = factorize_tiles(
        &mut tiles,
        Variant::ThreePrecision { dp_thick: 1, sp_thick: 2 },
        &NativeBackend,
        &sched,
    );
    assert!(
        r.is_ok(),
        "DP diagonal band must keep the factorization alive: {:?}",
        r.err().map(|e| e.to_string())
    );
}

#[test]
fn corrupted_artifacts_dir_reports_artifact_error() {
    let r = mpcholesky::runtime::PjrtBackend::load("/nonexistent/path");
    match r {
        Err(Error::Artifact(msg)) => assert!(msg.contains("manifest")),
        other => panic!("expected Artifact error, got {:?}", other.err().map(|e| e.to_string())),
    }
}

#[test]
fn truncated_manifest_rejected() {
    let dir = std::env::temp_dir().join("mpchol_bad_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), "# nb=64\ngemm_f64\tbroken").unwrap();
    match mpcholesky::runtime::Manifest::load(&dir) {
        Err(Error::Artifact(_)) => {}
        other => panic!("expected Artifact error, got {other:?}"),
    }
}

//! Property-based tests (hand-rolled generators on xoshiro — `proptest`
//! is unavailable offline).  Each property runs across a randomized
//! parameter sweep; failures print the seed for reproduction.

use mpcholesky::cholesky::{factorize_dense, solve_lower, solve_lower_transposed, Variant};
use mpcholesky::datagen::morton_sort;
use mpcholesky::kernels::NativeBackend;
use mpcholesky::matern::{matern_matrix, Location, MaternParams, Metric};
use mpcholesky::prelude::*;
use mpcholesky::scheduler::{Access, Scheduler, SchedulerConfig, SchedulingPolicy, TaskGraph};
use mpcholesky::tile::{DenseMatrix, TileId};

struct Sweep {
    rng: Xoshiro256pp,
}

impl Sweep {
    fn new(seed: u64) -> Self {
        Self { rng: Xoshiro256pp::seed_from_u64(seed) }
    }
    fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.rng.next_u64_raw() % (hi - lo + 1) as u64) as usize
    }
    fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.uniform() * (hi - lo)
    }
}

fn matern_dense(n: usize, seed: u64, theta: &MaternParams) -> DenseMatrix {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut locs: Vec<Location> = (0..n)
        .map(|_| Location::new(r.uniform_open(0.0, 1.0), r.uniform_open(0.0, 1.0)))
        .collect();
    morton_sort(&mut locs);
    DenseMatrix::from_vec(n, matern_matrix(&locs, theta, Metric::Euclidean, 1e-8)).unwrap()
}

/// Property: for every (nb, diag_thick, theta) the mixed factor
/// reconstructs A to f32-level accuracy: ||L L^T - A||_max bounded.
#[test]
fn prop_mixed_reconstruction_bounded() {
    let mut sweep = Sweep::new(101);
    for case in 0..8 {
        let nb = [16, 32][sweep.usize_in(0, 1)];
        let p = sweep.usize_in(3, 6);
        let n = nb * p;
        let thick = sweep.usize_in(1, p);
        let range = sweep.f64_in(0.02, 0.25);
        let theta = MaternParams::new(sweep.f64_in(0.5, 3.0), range, 0.5);
        let a = matern_dense(n, 200 + case, &theta);
        let sched = Scheduler::with_workers(4);
        let l = factorize_dense(&a, nb, Variant::MixedPrecision { diag_thick: thick },
            &NativeBackend, &sched)
            .unwrap()
            .to_dense(true);
        let llt = l.matmul_nt(&l);
        let mut err = 0.0f64;
        for j in 0..n {
            for i in j..n {
                err = err.max((llt.get(i, j) - a.get(i, j)).abs());
            }
        }
        let bound = 64.0 * f32::EPSILON as f64 * theta.variance * n as f64;
        assert!(err < bound, "case {case}: nb={nb} p={p} t={thick}: err {err} > {bound}");
    }
}

/// Property: solve(chol(A), A x) == x for arbitrary x (round trip through
/// the tile solves).
#[test]
fn prop_solve_inverts_matvec() {
    let mut sweep = Sweep::new(55);
    for case in 0..6 {
        let nb = 32;
        let p = sweep.usize_in(2, 5);
        let n = nb * p;
        let theta = MaternParams::new(1.0, sweep.f64_in(0.03, 0.15), 0.5);
        let a = matern_dense(n, 300 + case, &theta);
        let sched = Scheduler::with_workers(2);
        let l = factorize_dense(&a, nb, Variant::FullDp, &NativeBackend, &sched).unwrap();
        let mut r = Xoshiro256pp::seed_from_u64(400 + case);
        let x: Vec<f64> = (0..n).map(|_| r.standard_normal()).collect();
        let b = a.matvec(&x);
        let y = solve_lower(&l, &b).unwrap();
        let got = solve_lower_transposed(&l, &y).unwrap();
        for (u, v) in got.iter().zip(x.iter()) {
            assert!((u - v).abs() < 1e-6, "case {case}: {u} vs {v}");
        }
    }
}

/// Property: the scheduler never executes a task before its
/// dependencies, under randomized graphs, worker counts, and policies.
#[test]
fn prop_scheduler_respects_random_dags() {
    use std::sync::atomic::{AtomicU64, Ordering};
    let mut sweep = Sweep::new(77);
    for case in 0..10 {
        let tiles = sweep.usize_in(2, 6);
        let ntasks = sweep.usize_in(5, 60);
        let workers = sweep.usize_in(1, 8);
        let policy = [
            SchedulingPolicy::Fifo,
            SchedulingPolicy::Lifo,
            SchedulingPolicy::CriticalPath,
            SchedulingPolicy::PrecisionFrontier,
        ][sweep.usize_in(0, 3)];
        let mut g: TaskGraph<usize> = TaskGraph::new();
        let mut edges: Vec<(usize, usize)> = Vec::new();
        for t in 0..ntasks {
            let na = sweep.usize_in(1, 3);
            let mut acc = Vec::new();
            for _ in 0..na {
                let i = sweep.usize_in(0, tiles - 1);
                let j = sweep.usize_in(0, i);
                let write = sweep.usize_in(0, 1) == 1;
                acc.push((
                    TileId::new(i, j),
                    if write { Access::Write } else { Access::Read },
                ));
            }
            let before = g.len();
            g.submit(t, acc);
            // record inferred predecessor edges for post-hoc checking
            for (pi, pt) in g.tasks().iter().enumerate().take(before) {
                if pt.successors.contains(&before) {
                    edges.push((pi, before));
                }
            }
        }
        let stamps: Vec<AtomicU64> = (0..ntasks).map(|_| AtomicU64::new(0)).collect();
        let ctr = AtomicU64::new(1);
        let sched = Scheduler::new(SchedulerConfig { num_workers: workers, policy, trace: false });
        sched
            .run(&mut g, |idx, _| {
                stamps[idx].store(ctr.fetch_add(1, Ordering::SeqCst), Ordering::SeqCst);
                Ok(())
            })
            .unwrap();
        for &(a, b) in &edges {
            let (sa, sb) = (
                stamps[a].load(Ordering::SeqCst),
                stamps[b].load(Ordering::SeqCst),
            );
            assert!(
                sa < sb,
                "case {case} (policy {policy:?}, workers {workers}): edge {a}->{b} violated"
            );
        }
    }
}

/// Property: DST's factor reproduces the banded covariance exactly and
/// never touches off-band tiles (structure preservation).
#[test]
fn prop_dst_structure_preserved() {
    let mut sweep = Sweep::new(91);
    for case in 0..5 {
        let nb = 32;
        let p = sweep.usize_in(3, 6);
        let n = nb * p;
        let thick = sweep.usize_in(2, p); // thick >= 2 keeps weak fields PD
        let theta = MaternParams::new(1.0, 0.02, 0.5);
        let a = matern_dense(n, 500 + case, &theta);
        let sched = Scheduler::with_workers(3);
        let Ok(tiles) =
            factorize_dense(&a, nb, Variant::Dst { diag_thick: thick }, &NativeBackend, &sched)
        else {
            continue; // genuinely lost PD; allowed for thin bands
        };
        let l = tiles.to_dense(true);
        for bj in 0..p {
            for bi in (bj + thick)..p {
                for c in 0..nb {
                    for r in 0..nb {
                        assert_eq!(
                            l.get(bi * nb + r, bj * nb + c),
                            0.0,
                            "case {case}: fill-in outside band"
                        );
                    }
                }
            }
        }
    }
}

/// Property: the band variants' PrecisionMap agrees exactly with the
/// legacy per-tile band predicates for every (i, j) — the refactor moved
/// the decision behind the map without changing it.
#[test]
fn prop_band_map_matches_band_predicates() {
    for p in [1usize, 2, 5, 9] {
        for variant in [
            Variant::FullDp,
            Variant::MixedPrecision { diag_thick: 2 },
            Variant::Dst { diag_thick: 3 },
            Variant::ThreePrecision { dp_thick: 1, sp_thick: 3 },
        ] {
            let map = variant.precision_map(p, None).unwrap();
            for j in 0..p {
                for i in j..p {
                    assert_eq!(
                        map.get(i, j),
                        variant.tile_precision(i, j),
                        "{variant:?} tile ({i},{j})"
                    );
                    assert_eq!(map.is_dp(i, j), variant.is_dp_tile(i, j, p));
                }
            }
        }
    }
}

/// Properties of the adaptive map on real covariance tiles:
/// * tolerance 0 demotes nothing (equals the full-DP band);
/// * every diagonal tile stays F64 at every tolerance;
/// * lookups are symmetric-consistent;
/// * monotone in tolerance — loosening never *promotes* a tile.
#[test]
fn prop_adaptive_map_invariants() {
    use mpcholesky::tile::{Precision, PrecisionMap, TileMatrix};
    let mut sweep = Sweep::new(123);
    for case in 0..5 {
        let nb = 16;
        let p = sweep.usize_in(3, 8);
        let n = nb * p;
        let theta = MaternParams::new(sweep.f64_in(0.5, 2.0), sweep.f64_in(0.03, 0.2), 0.5);
        let a = matern_dense(n, 700 + case, &theta);
        let tiles = TileMatrix::from_dense(&a, nb).unwrap();

        let zero = PrecisionMap::adaptive(&tiles, 0.0);
        let dp_band = Variant::FullDp.precision_map(p, None).unwrap();
        assert_eq!(zero, dp_band, "case {case}: tolerance 0 must equal the DP band");

        let tols = [1e-14, 1e-10, 1e-8, 1e-6, 1e-3, 1e-1];
        let maps: Vec<PrecisionMap> =
            tols.iter().map(|&t| PrecisionMap::adaptive(&tiles, t)).collect();
        for (m, &tol) in maps.iter().zip(&tols) {
            for k in 0..p {
                assert_eq!(m.get(k, k), Precision::F64, "case {case} tol {tol}: diag demoted");
            }
            for j in 0..p {
                for i in 0..p {
                    assert_eq!(m.get(i, j), m.get(j, i), "case {case}: asymmetric lookup");
                }
            }
        }
        // Precision orders Bf16 < F32 < F64; looser tolerance must never
        // increase a tile's precision
        for w in maps.windows(2) {
            let (tight, loose) = (&w[0], &w[1]);
            for j in 0..p {
                for i in j..p {
                    assert!(
                        loose.get(i, j) <= tight.get(i, j),
                        "case {case}: loosening promoted tile ({i},{j})"
                    );
                }
            }
        }
    }
}

/// Property: kriging at observed sites reproduces observations (exact
/// interpolation, tiny nugget) for random fields and variants.
#[test]
fn prop_kriging_interpolates() {
    let mut sweep = Sweep::new(33);
    for case in 0..4 {
        let range = sweep.f64_in(0.05, 0.3);
        let f = SyntheticField::generate(&FieldConfig {
            n: 256,
            theta: MaternParams::new(1.0, range, 0.5),
            seed: 600 + case,
            ..Default::default()
        })
        .unwrap();
        let variant = if case % 2 == 0 {
            Variant::FullDp
        } else {
            Variant::MixedPrecision { diag_thick: 2 }
        };
        let cfg = MleConfig { nb: 64, variant, ..Default::default() };
        let model = KrigingModel::fit(&f.locations, &f.values, f.theta, &cfg).unwrap();
        let back = model.predict(&f.locations[..16]);
        for (p, t) in back.iter().zip(f.values[..16].iter()) {
            assert!((p - t).abs() < 2e-3, "case {case} ({variant:?}): {p} vs {t}");
        }
    }
}

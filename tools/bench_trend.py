#!/usr/bin/env python3
"""Fold bench_cholesky JSON snapshots into one markdown trend table.

Usage:
    python3 tools/bench_trend.py FILE_OR_DIR... [--out BENCH_trend.md]

Each input is a `bench_cholesky --json` snapshot (or a directory of
them, e.g. per-push CI artifacts downloaded side by side).  The output
is a markdown table with one row per (variant, nb) case and one column
per snapshot, carrying `GFLOP/s` plus the epilogue's solve-time share —
enough to eyeball a perf trajectory across pushes, policies, or fused
vs unfused lowering without spreadsheet work.

Snapshots are column-labelled by file stem (`BENCH_policy_pf` ->
`policy_pf`); rows missing from a snapshot render as `-`.
"""

import argparse
import json
import sys
from pathlib import Path


def collect(paths, seen=None):
    """Yield (label, parsed json) per snapshot file, directories expanded.

    Labels are file stems; same-named files from different directories
    (the per-push artifact layout) are disambiguated with their parent
    directory so columns never silently overwrite each other.
    """
    if seen is None:
        seen = set()
    for p in paths:
        path = Path(p)
        if path.is_dir():
            yield from collect(sorted(path.glob("*.json")), seen)
            continue
        if not path.exists():
            print(f"bench_trend: skipping missing {path}", file=sys.stderr)
            continue
        try:
            data = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            print(f"bench_trend: skipping unparsable {path}: {e}", file=sys.stderr)
            continue
        if data.get("bench") != "cholesky" or "results" not in data:
            print(f"bench_trend: skipping non-bench json {path}", file=sys.stderr)
            continue
        label = path.stem
        if label.startswith("BENCH_"):
            label = label[len("BENCH_"):]
        if label in seen:
            label = f"{path.parent.name}/{label}"
        k = 2
        base = label
        while label in seen:
            label = f"{base}#{k}"
            k += 1
        seen.add(label)
        yield label, data


def cell(row):
    """Render one snapshot's cell for a case row."""
    gflops = row.get("gflops", 0.0)
    out = f"{gflops:.2f}"
    # epilogue share: solve span time over the run's wall time
    solve_ns = row.get("solve_ns")
    median_s = row.get("median_s", 0.0)
    if solve_ns is not None and median_s > 0:
        out += f" ({100.0 * solve_ns / 1e9 / median_s:.1f}%)"
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("inputs", nargs="+", help="bench JSON files or directories")
    ap.add_argument("--out", default="BENCH_trend.md", help="markdown output path")
    args = ap.parse_args()

    snapshots = list(collect(args.inputs))
    if not snapshots:
        print("bench_trend: no usable snapshots", file=sys.stderr)
        return 1

    # case key -> {snapshot label -> row}; fused and unfused runs of the
    # same (variant, nb) are distinct cases so the head-to-head
    # comparison reads off adjacent rows instead of clobbering a column
    cases = {}
    for label, data in snapshots:
        for row in data["results"]:
            key = (row["variant"], row["nb"], bool(row.get("fused_gemm", False)))
            cases.setdefault(key, {})[label] = row

    labels = [label for label, _ in snapshots]
    lines = [
        "# bench_cholesky trend",
        "",
        "GFLOP/s per (variant, nb, fused) case; parenthesized percentage is",
        "the solve/log-det epilogue's share of the run's wall time.  The",
        "`fused` column separates fused-GemmBatch lowering from per-update",
        "gemm tasks (`--fused` bench legs).",
        "",
        "| variant | nb | fused | " + " | ".join(labels) + " |",
        "|---|---|---|" + "---|" * len(labels),
    ]
    for (variant, nb, fused), per_snap in sorted(cases.items()):
        cells = [cell(per_snap[l]) if l in per_snap else "-" for l in labels]
        fused_mark = "yes" if fused else "no"
        lines.append(f"| {variant} | {nb} | {fused_mark} | " + " | ".join(cells) + " |")
    lines.append("")

    Path(args.out).write_text("\n".join(lines))
    print(f"bench_trend: wrote {args.out} ({len(cases)} cases x {len(labels)} snapshots)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

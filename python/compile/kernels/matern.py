"""Pallas Matern covariance tile generation (paper Eq. 1 / SSIV-B).

Builds one (bm, bn) tile of the covariance matrix Sigma(theta) from two
coordinate blocks.  Matrix generation is ExaGeoStat's second hot spot (it
re-runs at every MLE iteration with a fresh theta), and it is embarrassingly
tile-parallel, so the grid maps directly onto output blocks with the two
coordinate panels streamed into VMEM.

Smoothness is a *static* kernel parameter restricted to the half-integer
closed forms {0.5, 1.5, 2.5} — these lower to exp/mul only, which both the
TPU VPU and the CPU backend execute natively.  The continuous-nu Matern
(needed by the MLE optimizer, which searches over theta_3) lives in the
Rust substrate (`matern/bessel.rs`), where the Temme-series Bessel K_nu is
cheap scalar code; cutting HLO artifacts per-nu would otherwise require
re-lowering inside the optimization loop, putting Python back on the
request path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gemm import DEFAULT_BLOCK, pick_block

jax.config.update("jax_enable_x64", True)

HALF_INT_NUS = (0.5, 1.5, 2.5)


def _matern_kernel(x1_ref, x2_ref, theta_ref, o_ref, *, nu):
    x1 = x1_ref[...]  # (bm, 2)
    x2 = x2_ref[...]  # (bn, 2)
    var = theta_ref[0]
    rng = theta_ref[1]
    diff = x1[:, None, :] - x2[None, :, :]
    r2 = jnp.sum(diff * diff, axis=-1)
    r = jnp.sqrt(r2)
    d = r / rng
    if nu == 0.5:
        poly = jnp.ones_like(d)
    elif nu == 1.5:
        poly = 1.0 + d
    elif nu == 2.5:
        poly = 1.0 + d + d * d / 3.0
    else:  # pragma: no cover
        raise ValueError(f"static nu must be in {HALF_INT_NUS}, got {nu}")
    cov = var * poly * jnp.exp(-d)
    # exact-zero distance (tile on the diagonal) -> C(0) = variance
    o_ref[...] = jnp.where(r2 == 0.0, var, cov)


@functools.partial(jax.jit, static_argnames=("nu", "block"))
def matern(x1, x2, theta, *, nu: float, block: int = DEFAULT_BLOCK):
    """Covariance tile C(||x1_i - x2_j||; theta) for nu in {0.5, 1.5, 2.5}.

    x1: (m, 2), x2: (n, 2), theta: (3,) = (variance, range, smoothness);
    theta[2] is carried for calling-convention parity with the Rust side
    but the smoothness actually applied is the static `nu`.
    """
    m, n = x1.shape[0], x2.shape[0]
    bm, bn = pick_block(m, block), pick_block(n, block)
    return pl.pallas_call(
        functools.partial(_matern_kernel, nu=nu),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 2), lambda i, j: (j, 0)),
            pl.BlockSpec((3,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x1.dtype),
        interpret=True,
    )(x1, x2, theta)


def matern_nu05(x1, x2, theta):
    return matern(x1, x2, theta, nu=0.5)


def matern_nu15(x1, x2, theta):
    return matern(x1, x2, theta, nu=1.5)


def matern_nu25(x1, x2, theta):
    return matern(x1, x2, theta, nu=2.5)

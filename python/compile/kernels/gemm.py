"""Pallas tile GEMM: C <- C - A @ B^T.

This is the paper's dominant kernel — the trailing-matrix update of the
right-looking tile Cholesky (Algorithm 1 lines 23-29, `dgemm`/`sgemm`) is
where the O(n^3) flops live.  The mixed-precision contribution is expressed
here as a *dtype-parametric* kernel: the f64 instantiation is the paper's
`dgemm`, the f32 instantiation its `sgemm`, and a bf16-input/f32-accumulate
instantiation covers the paper's SIX.future-work third precision level on
MXU-style hardware.

TPU mapping (DESIGN.md SS2): the (bm, bn) output block lives in VMEM, the
full-k panels of A and B are streamed per grid step by BlockSpec, and the
inner `dot_general` is the MXU contraction with `preferred_element_type`
pinning the accumulator precision — the Pallas analog of WMMA/tensor-core
accumulate the paper's GPU runs got from cuBLAS.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, and interpret mode lowers to plain HLO so the AOT artifact is
loadable from Rust.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

# Default VMEM block edge.  16 MiB VMEM / (3 tiles * 8 B) supports well
# beyond 128; 64 keeps the interpret-mode test matrix cheap while exercising
# a multi-block grid for every tile size >= 128.
DEFAULT_BLOCK = 64


def pick_block(dim: int, block: int) -> int:
    """Largest divisor of `dim` that is <= `block` (BlockSpec grids must
    tile the array exactly; tile sizes are caller-chosen so uneven shapes
    are legal inputs)."""
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


def _gemm_kernel(c_ref, a_ref, b_ref, o_ref, *, acc_dtype):
    """One (bm, bn) output block: o = c - a @ b^T with acc in acc_dtype."""
    acc = jax.lax.dot_general(
        a_ref[...],
        b_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype,
    )
    o_ref[...] = c_ref[...] - acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def gemm(c, a, b, *, block: int = DEFAULT_BLOCK):
    """C - A @ B^T over (nb, nb) tiles.

    c: (m, n), a: (m, k), b: (n, k).  All three share a dtype; bf16 inputs
    accumulate in f32, f32/f64 accumulate natively (matching what MKL's
    sgemm/dgemm — the paper's codelets — do).
    """
    m, n = c.shape
    k = a.shape[1]
    bm, bn = pick_block(m, block), pick_block(n, block)
    acc_dtype = jnp.float32 if c.dtype == jnp.bfloat16 else c.dtype
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),  # C block
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),  # A panel (full k)
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),  # B panel (full k)
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=True,
    )(c, a, b)


def gemm_f64(c, a, b):
    """Paper's `dgemm` codelet."""
    return gemm(c, a, b)


def gemm_f32(c, a, b):
    """Paper's `sgemm` codelet."""
    return gemm(c, a, b)


def gemm_bf16(c, a, b):
    """Third precision level (paper SSIX future work): bf16 in, f32 acc."""
    return gemm(c, a, b)

"""Pallas tile TRSM: B <- B @ L^{-T} (Algorithm 1 lines 12/14, `dtrsm`/`strsm`).

The panel solve of the right-looking tile Cholesky: after `potrf` factors
the diagonal tile L = chol(A_kk), every tile below it in column k is
replaced by A_ik L^{-T}.

Row independence is the parallel structure: in X L^T = B every *row* of B
is an independent triangular solve, so the Pallas grid splits B into row
blocks (each an independent kernel instance — the threadblock analog) and
each instance runs a vectorized forward substitution over the nb columns
with the full L tile resident in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gemm import DEFAULT_BLOCK, pick_block

jax.config.update("jax_enable_x64", True)


def _trsm_kernel(l_ref, b_ref, o_ref):
    """Solve X L^T = B for one (bm, nb) row block of B.

    Forward substitution, one column at a time:
        x_j = (b_j - sum_{k<j} x_k * L[j,k]) / L[j,j]
    Unsolved columns of the accumulator are kept at zero so the masked
    dot with row j of L only picks up already-solved columns.
    """
    l = l_ref[...]
    b = b_ref[...]
    nb = l.shape[0]
    cols = jnp.arange(nb)

    def body(j, x):
        lrow = jax.lax.dynamic_slice_in_dim(l, j, 1, axis=0)[0]  # (nb,)
        partial = x @ jnp.where(cols < j, lrow, 0).astype(x.dtype)  # (bm,)
        bj = jax.lax.dynamic_slice_in_dim(b, j, 1, axis=1)[:, 0]
        diag = jax.lax.dynamic_index_in_dim(lrow, j, keepdims=False)
        xj = (bj - partial) / diag
        return jax.lax.dynamic_update_slice_in_dim(x, xj[:, None], j, axis=1)

    x = jax.lax.fori_loop(0, nb, body, jnp.zeros_like(b))
    o_ref[...] = x


@functools.partial(jax.jit, static_argnames=("block",))
def trsm(l, b, *, block: int = DEFAULT_BLOCK):
    """B @ L^{-T} for a lower-triangular (nb, nb) L and an (m, nb) B."""
    m, nb = b.shape
    bm = pick_block(m, block)
    return pl.pallas_call(
        _trsm_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((nb, nb), lambda i: (0, 0)),  # full L in VMEM
            pl.BlockSpec((bm, nb), lambda i: (i, 0)),  # row block of B
        ],
        out_specs=pl.BlockSpec((bm, nb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, nb), b.dtype),
        interpret=True,
    )(l, b)


def trsm_f64(l, b):
    """Paper's `dtrsm` codelet."""
    return trsm(l, b)


def trsm_f32(l, b):
    """Paper's `strsm` codelet (operates on the demoted diagonal copy)."""
    return trsm(l, b)

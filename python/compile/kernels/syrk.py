"""Pallas tile SYRK: C <- C - A @ A^T (Algorithm 1 line 19, `dsyrk`).

Updates a diagonal tile of the trailing matrix.  In the paper's algorithm
the diagonal tiles are *always* double precision, but the panel tile A that
feeds the update may have been computed in single precision (then promoted
by `sconv2d`, line 15) — so the kernel itself is dtype-parametric like
`gemm`, and the precision policy lives in Layer 2 / the Rust coordinator.

Only the lower triangle of C is meaningful to the factorization; we update
the full tile (the rank-k update of a symmetric C stays symmetric, and a
full (bm, bn) block update keeps the MXU contraction dense instead of
masking half the systolic array).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gemm import DEFAULT_BLOCK, pick_block

jax.config.update("jax_enable_x64", True)


def _syrk_kernel(c_ref, al_ref, ar_ref, o_ref, *, acc_dtype):
    acc = jax.lax.dot_general(
        al_ref[...],
        ar_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype,
    )
    o_ref[...] = c_ref[...] - acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def syrk(c, a, *, block: int = DEFAULT_BLOCK):
    """C - A @ A^T for an (n, n) diagonal tile C and (n, k) panel A.

    A is passed twice with different BlockSpecs (row-panel i and row-panel
    j) — in VMEM terms both panels are resident, which is the same
    footprint a masked triangular update would need.
    """
    n = c.shape[0]
    k = a.shape[1]
    bn = pick_block(n, block)
    acc_dtype = jnp.float32 if c.dtype == jnp.bfloat16 else c.dtype
    grid = (n // bn, n // bn)
    return pl.pallas_call(
        functools.partial(_syrk_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bn), lambda i, j: (i, j)),  # C block
            pl.BlockSpec((bn, k), lambda i, j: (i, 0)),  # A row-panel i
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),  # A row-panel j
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), c.dtype),
        interpret=True,
    )(c, a, a)


def syrk_f64(c, a):
    """Paper's `dsyrk` codelet."""
    return syrk(c, a)


def syrk_f32(c, a):
    """Single-precision instantiation (used by the bf16/f32/f64 extension)."""
    return syrk(c, a)

"""Pure-jnp correctness oracles for the Pallas tile kernels.

Every Layer-1 kernel in this package has an oracle here with the *same
calling convention*; `python/tests/` asserts allclose between the two over
hypothesis-driven shape/dtype/seed sweeps.  The oracles are deliberately
written with the most obvious jnp expression available (no Pallas, no
manual blocking) so a disagreement always indicts the kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def gemm_ref(c, a, b):
    """C - A @ B^T, accumulation in the output dtype's precision."""
    return c - a @ b.T


def syrk_ref(c, a):
    """C - A @ A^T (full tile; symmetric rank-k update of a diagonal tile)."""
    return c - a @ a.T


def trsm_ref(l, b):
    """B @ L^{-T}: the right-looking panel solve A_ik <- A_ik * L_kk^{-T}.

    Solving X L^T = B for X is equivalent to L X^T = B^T (forward
    substitution on the transpose).
    """
    xt = jax.scipy.linalg.solve_triangular(l, b.T, lower=True)
    return xt.T


def potrf_ref(a):
    """Lower Cholesky factor of an SPD tile."""
    return jnp.linalg.cholesky(a)


def lag2s_ref(a):
    """dlag2s: demote an f64 tile to f32 (the paper stores the demoted copy
    transposed in the upper triangle; the transpose is a storage detail
    handled by the Rust tile layer, not the numeric kernel)."""
    return a.astype(jnp.float32)


def lag2d_ref(a):
    """slag2d: promote an f32 tile back to f64."""
    return a.astype(jnp.float64)


def _matern_halfint(r, variance, rng, nu):
    """Matern closed forms for half-integer smoothness (Eq. 1 of the paper).

    nu = 0.5:  sigma^2 exp(-d)
    nu = 1.5:  sigma^2 (1 + d) exp(-d)
    nu = 2.5:  sigma^2 (1 + d + d^2/3) exp(-d)
    with d = r / rng (the paper's r/theta2 parameterisation).
    """
    d = r / rng
    if nu == 0.5:
        poly = 1.0
    elif nu == 1.5:
        poly = 1.0 + d
    elif nu == 2.5:
        poly = 1.0 + d + d * d / 3.0
    else:  # pragma: no cover - guarded by callers
        raise ValueError(f"half-integer form only for nu in {{0.5,1.5,2.5}}, got {nu}")
    return variance * poly * jnp.exp(-d)


def matern_ref(x1, x2, theta, nu):
    """Covariance tile Sigma_ij = C(||x1_i - x2_j||; theta) (Eq. 1).

    x1: (m, 2) coordinates, x2: (n, 2) coordinates, theta = (variance,
    range, _), nu in {0.5, 1.5, 2.5}.  The zero-distance limit is the
    variance (C(0) = theta_1).
    """
    diff = x1[:, None, :] - x2[None, :, :]
    r = jnp.sqrt(jnp.sum(diff * diff, axis=-1))
    cov = _matern_halfint(r, theta[0], theta[1], nu)
    return jnp.where(r == 0.0, theta[0], cov)


def matern_general_ref(x1, x2, theta):
    """General-smoothness Matern oracle via scipy's Bessel K_nu.

    Used only as a *test oracle* (for the Pallas matern kernel at
    half-integer nu, and to cut golden files for the Rust bessel/matern
    substrate); never shipped as an artifact.  theta = (variance, range,
    smoothness).
    """
    import numpy as np
    from scipy.special import gamma, kv

    x1 = np.asarray(x1)
    x2 = np.asarray(x2)
    var, rng, nu = float(theta[0]), float(theta[1]), float(theta[2])
    diff = x1[:, None, :] - x2[None, :, :]
    r = np.sqrt(np.sum(diff * diff, axis=-1))
    d = r / rng
    scale = var / (2.0 ** (nu - 1.0) * gamma(nu))
    with np.errstate(invalid="ignore", divide="ignore"):
        cov = scale * d**nu * kv(nu, d)
    return np.where(r == 0.0, var, cov)

"""Layer-1 Pallas tile kernels for the mixed-precision tile Cholesky.

All kernels run under interpret=True (CPU-PJRT-loadable HLO); see each
module's docstring for the TPU/MXU mapping and DESIGN.md SS2 for the
hardware-adaptation rationale.
"""

from .gemm import gemm, gemm_bf16, gemm_f32, gemm_f64
from .matern import HALF_INT_NUS, matern, matern_nu05, matern_nu15, matern_nu25
from .potrf import potrf, potrf_f32, potrf_f64
from .syrk import syrk, syrk_f32, syrk_f64
from .trsm import trsm, trsm_f32, trsm_f64

__all__ = [
    "gemm", "gemm_f64", "gemm_f32", "gemm_bf16",
    "syrk", "syrk_f64", "syrk_f32",
    "trsm", "trsm_f64", "trsm_f32",
    "potrf", "potrf_f64", "potrf_f32",
    "matern", "matern_nu05", "matern_nu15", "matern_nu25", "HALF_INT_NUS",
]

"""Pallas tile POTRF: lower Cholesky of one SPD tile (Algorithm 1 line 8).

The diagonal-tile factorization is inherently sequential in its column
dependence, so there is nothing for a Pallas *grid* to parallelize at
nb <= 256 — the kernel is a single instance holding the tile in VMEM and
running a vectorized left-looking column sweep (each column update is a
rank-(j) masked mat-vec that the VPU/MXU executes densely).

The paper always runs this tile in double precision (a single-precision
diagonal can lose positive-definiteness and abort the MLE — SSVIII.D.1);
the f32 instantiation exists for the DST/ablation paths and tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _potrf_kernel(a_ref, o_ref):
    """Left-looking column Cholesky over the whole tile.

    For column j (with already-factored columns 0..j-1 of L stored in x):
        c    = a[:, j] - sum_{k<j} x[:, k] * x[j, k]
        L[j:, j] = c[j:] / sqrt(c[j]),  L[:j, j] = 0
    The masked row extraction keeps the update branch-free.
    """
    a = a_ref[...]
    nb = a.shape[0]
    cols = jnp.arange(nb)

    def body(j, x):
        aj = jax.lax.dynamic_slice_in_dim(a, j, 1, axis=1)[:, 0]  # (nb,)
        xrow = jax.lax.dynamic_slice_in_dim(x, j, 1, axis=0)[0]  # (nb,)
        xrow = jnp.where(cols < j, xrow, 0).astype(x.dtype)
        c = aj - x @ xrow
        d = jnp.sqrt(jax.lax.dynamic_index_in_dim(c, j, keepdims=False))
        col = jnp.where(cols >= j, c / d, 0).astype(x.dtype)
        return jax.lax.dynamic_update_slice_in_dim(x, col[:, None], j, axis=1)

    o_ref[...] = jax.lax.fori_loop(0, nb, body, jnp.zeros_like(a))


@jax.jit
def potrf(a):
    """Lower Cholesky factor of an SPD (nb, nb) tile; strict upper = 0."""
    nb = a.shape[0]
    return pl.pallas_call(
        _potrf_kernel,
        out_shape=jax.ShapeDtypeStruct((nb, nb), a.dtype),
        interpret=True,
    )(a)


def potrf_f64(a):
    """Paper's `dpotrf` codelet."""
    return potrf(a)


def potrf_f32(a):
    """Single-precision instantiation (ablations / SP(100%) failure demo)."""
    return potrf(a)

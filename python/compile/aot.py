"""AOT lowering: JAX/Pallas kernels -> HLO *text* artifacts for the Rust
PJRT runtime (`rust/src/runtime/`).

Run once by `make artifacts`; Python never executes on the request path.

Interchange is HLO text, NOT `lowered.compile().serialize()` — jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the xla crate's
bundled xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README).

Every artifact is listed in `artifacts/manifest.txt` as
    name <TAB> arg0_shape:dtype, arg1_shape:dtype, ... <TAB> out_shape:dtype
which the Rust executable registry parses at startup instead of trusting
hard-coded shapes.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import gemm, matern, potrf, syrk, trsm  # noqa: E402

# Build-time tile size for per-kernel artifacts.  The Rust native backend
# supports any nb; the PJRT backend is fixed to this at build time (one
# compiled executable per kernel), mirroring how ExaGeoStat fixes nb per run.
NB = int(os.environ.get("MPCHOL_NB", "64"))

# Fused-demo sizes (small: the demo certifies composition, not scale).
DEMO_N = 256
DEMO_NB = 64
DEMO_THICK = 2
DEMO_NU = 0.5


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _fmt(s: jax.ShapeDtypeStruct) -> str:
    return f"{'x'.join(map(str, s.shape))}:{jnp.dtype(s.dtype).name}"


def artifact_table():
    """name -> (fn, [arg specs], out spec).  One entry per HLO module."""
    f64, f32 = jnp.float64, jnp.float32
    t64 = _spec((NB, NB), f64)
    t32 = _spec((NB, NB), f32)
    tb = {}

    def add(name, fn, args, out):
        tb[name] = (fn, args, out)

    # Tile BLAS, both precisions (paper's d*/s* codelets)
    add("gemm_f64", lambda c, a, b: gemm(c, a, b), [t64, t64, t64], t64)
    add("gemm_f32", lambda c, a, b: gemm(c, a, b), [t32, t32, t32], t32)
    add("syrk_f64", lambda c, a: syrk(c, a), [t64, t64], t64)
    add("syrk_f32", lambda c, a: syrk(c, a), [t32, t32], t32)
    add("trsm_f64", lambda l, b: trsm(l, b), [t64, t64], t64)
    add("trsm_f32", lambda l, b: trsm(l, b), [t32, t32], t32)
    add("potrf_f64", potrf, [t64], t64)
    add("potrf_f32", potrf, [t32], t32)
    # Precision conversions (dlag2s / slag2d)
    add("lag2s", lambda a: a.astype(f32), [t64], t32)
    add("lag2d", lambda a: a.astype(f64), [t32], t64)
    # bf16 third-precision extension (paper SSIX future work)
    tb16 = _spec((NB, NB), jnp.bfloat16)
    add("gemm_bf16", lambda c, a, b: gemm(c, a, b), [tb16, tb16, tb16], tb16)
    # Matern covariance tile generation, one artifact per half-integer nu
    c64 = _spec((NB, 2), f64)
    th = _spec((3,), f64)
    for nu, tag in ((0.5, "nu05"), (1.5, "nu15"), (2.5, "nu25")):
        add(
            f"matern_{tag}",
            (lambda nu_: lambda x1, x2, t: matern(x1, x2, t, nu=nu_))(nu),
            [c64, c64, th],
            t64,
        )
    # Fused demos: the whole Algorithm 1 (and a full MLE iteration) as ONE
    # HLO program — L1+L2 composition proof, also used by rust tests as a
    # cross-check of the tiled runtime path.
    a_demo = _spec((DEMO_N, DEMO_N), f64)
    add(
        "mp_cholesky_demo",
        lambda a: model.mp_cholesky(a, nb=DEMO_NB, diag_thick=DEMO_THICK),
        [a_demo],
        a_demo,
    )
    locs = _spec((DEMO_N, 2), f64)
    z = _spec((DEMO_N,), f64)
    add(
        "mp_loglik_demo",
        lambda L, Z, T: model.mp_loglik(
            L, Z, T, nu=DEMO_NU, nb=DEMO_NB, diag_thick=DEMO_THICK
        ),
        [locs, z, th],
        _spec((), f64),
    )
    add("loglik_dense", model.loglik, [a_demo, z], _spec((), f64))
    return tb


def lower_one(name, fn, args):
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="sentinel path; artifacts land in its directory")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact names (default: all)")
    ns = ap.parse_args()
    outdir = os.path.dirname(os.path.abspath(ns.out)) or "."
    os.makedirs(outdir, exist_ok=True)

    table = artifact_table()
    names = ns.only.split(",") if ns.only else list(table)
    manifest = []
    for name in names:
        fn, args, out = table[name]
        text = lower_one(name, fn, args)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(
            f"{name}\t{','.join(_fmt(a) for a in args)}\t{_fmt(out)}"
        )
        print(f"  {name}: {len(text)} chars")

    with open(os.path.join(outdir, "manifest.txt"), "w") as f:
        f.write(f"# nb={NB} demo_n={DEMO_N} demo_nb={DEMO_NB} "
                f"demo_thick={DEMO_THICK} demo_nu={DEMO_NU}\n")
        f.write("\n".join(manifest) + "\n")
    # sentinel for the Makefile dependency
    with open(os.path.join(outdir, "model.hlo.txt"), "w") as f:
        f.write("\n".join(names) + "\n")
    print(f"wrote {len(names)} artifacts + manifest to {outdir}")


if __name__ == "__main__":
    main()

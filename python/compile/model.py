"""Layer-2 JAX model: Algorithm 1 (mixed-precision tile Cholesky) and the
Gaussian log-likelihood it drives (paper Eqs. 2-3), composed from the
Layer-1 Pallas tile kernels.

This module is the build-time *numerical specification* of what the Rust
coordinator executes at runtime: the same tile-level kernel sequence, the
same precision policy, expressed over a statically-unrolled p x p tile
grid so the whole factorization lowers to one fused HLO program
(`mp_cholesky_full` artifact — the proof that L1 kernels and L2
composition AOT together).

Precision policy (Algorithm 1): tile (i, j) of the lower triangle is
DOUBLE iff |i - j| < diag_thick, SINGLE otherwise.  Concretely per kernel:
  - potrf(k,k): always f64 (line 8).
  - trsm(i,k):  f64 if DP tile (line 12); else the f32 demoted copies of
    L_kk and A_ik (line 14) with the result promoted back (line 15).
  - syrk(j,j):  always f64 (line 19) — uses the promoted panel tiles.
  - gemm(i,j):  f64 if DP tile (line 25); else f32 on demoted copies
    (line 27).
The f32 round-trip (demote -> compute -> promote) is exactly how the paper
realizes single-precision tiles while keeping a full-precision storage slot
(upper triangle) — so emulating it by casts is bit-faithful, not an
approximation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import gemm, matern, potrf, syrk, trsm

jax.config.update("jax_enable_x64", True)

F32 = jnp.float32
F64 = jnp.float64


def _is_dp(i: int, j: int, diag_thick: int) -> bool:
    """Algorithm 1's precision predicate for tile (i, j)."""
    return abs(i - j) < diag_thick


def _split_tiles(a, nb: int):
    """View an (n, n) array as a dict {(i, j): (nb, nb) tile}, lower part."""
    p = a.shape[0] // nb
    return {
        (i, j): a[i * nb : (i + 1) * nb, j * nb : (j + 1) * nb]
        for i in range(p)
        for j in range(i + 1)
    }, p


def _join_tiles(tiles, p: int, nb: int, dtype=F64):
    """Reassemble the lower-triangular tile dict into a dense (n, n) array."""
    rows = []
    for i in range(p):
        row = [
            tiles[(i, j)].astype(dtype)
            if j <= i
            else jnp.zeros((nb, nb), dtype)
            for j in range(p)
        ]
        rows.append(jnp.concatenate(row, axis=1))
    return jnp.concatenate(rows, axis=0)


def mp_cholesky(a, *, nb: int, diag_thick: int):
    """Mixed-precision tile Cholesky (Algorithm 1), lower triangular.

    a: (n, n) SPD, n divisible by nb.  Returns the (n, n) lower factor in
    f64 storage; tiles outside the diag_thick band carry f32-accurate
    values (they were computed by strsm/sgemm on demoted data).
    """
    tiles, p = _split_tiles(a, nb)
    # Upper-triangle storage of the paper = a shadow dict of f32 copies.
    sp = {
        (i, j): tiles[(i, j)].astype(F32)
        for i in range(p)
        for j in range(i + 1)
        if not _is_dp(i, j, diag_thick)
    }

    for k in range(p):
        # line 8: diagonal factorization, always DP
        lkk = potrf(tiles[(k, k)])
        tiles[(k, k)] = lkk
        # line 9: demoted copy of the factored diagonal tile (tmp vector)
        lkk_s = lkk.astype(F32)

        # lines 10-17: panel solve
        for i in range(k + 1, p):
            if _is_dp(i, k, diag_thick):
                tiles[(i, k)] = trsm(lkk, tiles[(i, k)])  # line 12 dtrsm
            else:
                s = trsm(lkk_s, sp[(i, k)])  # line 14 strsm
                sp[(i, k)] = s
                tiles[(i, k)] = s.astype(F64)  # line 15 sconv2d

        # lines 18-30: trailing update
        for j in range(k + 1, p):
            # line 19: diagonal tile update, always DP (panel was promoted)
            tiles[(j, j)] = syrk(tiles[(j, j)], tiles[(j, k)])
            for i in range(j + 1, p):
                if _is_dp(i, j, diag_thick):
                    tiles[(i, j)] = gemm(
                        tiles[(i, j)], tiles[(i, k)], tiles[(j, k)]
                    )  # line 25 dgemm
                else:
                    aik_s = (
                        sp[(i, k)]
                        if (i, k) in sp
                        else tiles[(i, k)].astype(F32)  # lines 20-21 dconv2s
                    )
                    ajk_s = (
                        sp[(j, k)]
                        if (j, k) in sp
                        else tiles[(j, k)].astype(F32)
                    )
                    sp[(i, j)] = gemm(sp[(i, j)], aik_s, ajk_s)  # line 27
                    tiles[(i, j)] = sp[(i, j)].astype(F64)

    # zero the strict upper part of each diagonal tile (potrf kernel already
    # does this; keep the invariant explicit for _join_tiles)
    return _join_tiles(tiles, p, nb)


def dp_cholesky(a, *, nb: int):
    """Full double-precision tile Cholesky (the paper's DP(100%) baseline),
    same kernel sequence with the precision predicate always true."""
    return mp_cholesky(a, nb=nb, diag_thick=a.shape[0] // nb + 1)


def dst_cholesky(a, *, nb: int, diag_thick: int):
    """Diagonal-Super-Tile / independent-blocks baseline (paper SSV-B):
    tiles outside the band are *zeroed* before a DP factorization, which
    decouples the matrix into independent diagonal super-blocks."""
    n = a.shape[0]
    p = n // nb
    ti = jnp.arange(n) // nb
    band = jnp.abs(ti[:, None] - ti[None, :]) < diag_thick
    return dp_cholesky(jnp.where(band, a, 0.0), nb=nb)


def loglik(sigma, z):
    """Gaussian log-likelihood (Eq. 2) given a dense covariance and data.

    l(theta) = -n/2 log(2 pi) - 1/2 log|Sigma| - 1/2 z^T Sigma^{-1} z,
    evaluated through the Cholesky factor: log|Sigma| = 2 sum log diag L,
    and the quadratic form via one forward solve.
    """
    n = z.shape[0]
    l = jnp.linalg.cholesky(sigma)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diag(l)))
    u = jax.scipy.linalg.solve_triangular(l, z, lower=True)
    quad = jnp.sum(u * u)
    return -0.5 * n * jnp.log(2.0 * jnp.pi) - 0.5 * logdet - 0.5 * quad


def mp_loglik(locs, z, theta, *, nu: float, nb: int, diag_thick: int):
    """One full MLE iteration as a single fused graph: Matern covariance
    generation (L1 matern kernel, tile by tile) -> mixed-precision
    factorization -> log-determinant + quadratic form.

    This is the `mp_loglik_demo` artifact: it certifies that *everything*
    the Rust coordinator schedules at runtime also composes into one AOT
    HLO program (the L2 deliverable), even though Rust drives the tiled
    version for scalability.
    """
    n = locs.shape[0]
    p = n // nb
    rows = []
    for i in range(p):
        row = [
            matern(
                locs[i * nb : (i + 1) * nb],
                locs[j * nb : (j + 1) * nb],
                theta,
                nu=nu,
            )
            for j in range(p)
        ]
        rows.append(jnp.concatenate(row, axis=1))
    sigma = jnp.concatenate(rows, axis=0)

    l = mp_cholesky(sigma, nb=nb, diag_thick=diag_thick)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diag(l)))
    # forward solve against the mixed-precision factor, tile-free (the
    # solve is O(n^2); the paper keeps it DP)
    u = jax.scipy.linalg.solve_triangular(l, z, lower=True)
    quad = jnp.sum(u * u)
    return -0.5 * n * jnp.log(2.0 * jnp.pi) - 0.5 * logdet - 0.5 * quad

"""AOT path sanity: every artifact in the table lowers to non-trivial HLO
text with the declared entry signature, and the manifest format round-trips.

The actual load-and-execute check lives on the Rust side
(`rust/tests/pjrt_backend.rs`) — this guards the producer half.
"""

import re

import jax.numpy as jnp
import pytest

from compile import aot


@pytest.fixture(scope="module")
def table():
    return aot.artifact_table()


def test_table_covers_all_codelets(table):
    """Every kernel Algorithm 1 names must ship as an artifact in both
    precisions, plus conversions, matern generators and the fused demos."""
    need = {
        "gemm_f64", "gemm_f32", "syrk_f64", "syrk_f32",
        "trsm_f64", "trsm_f32", "potrf_f64", "potrf_f32",
        "lag2s", "lag2d", "gemm_bf16",
        "matern_nu05", "matern_nu15", "matern_nu25",
        "mp_cholesky_demo", "mp_loglik_demo", "loglik_dense",
    }
    assert need <= set(table)


@pytest.mark.parametrize(
    "name", ["gemm_f64", "gemm_f32", "potrf_f64", "lag2s", "matern_nu05"]
)
def test_lowering_produces_entry_computation(table, name):
    fn, args, _ = table[name]
    text = aot.lower_one(name, fn, args)
    assert "ENTRY" in text and "ROOT" in text
    # parameter count in the ENTRY computation (loop bodies are separate
    # computations with their own parameters) must match the declared arity
    entry = text[text.index("ENTRY"):]
    params = set(re.findall(r"parameter\((\d+)\)", entry))
    assert len(params) == len(args), (name, sorted(params))


def test_lowered_dtypes_match_manifest_decl(table):
    fn, args, out = table["gemm_f32"]
    text = aot.lower_one("gemm_f32", fn, args)
    assert "f32[64,64]" in text and "f64[" not in text


def test_f64_kernel_keeps_f64(table):
    fn, args, _ = table["gemm_f64"]
    text = aot.lower_one("gemm_f64", fn, args)
    assert "f64[64,64]" in text


def test_fmt_spec():
    s = aot._spec((64, 2), jnp.float64)
    assert aot._fmt(s) == "64x2:float64"
    assert aot._fmt(aot._spec((), jnp.float64)) == ":float64"

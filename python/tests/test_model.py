"""L2 model correctness: Algorithm 1 composition vs dense references, and
the accuracy-vs-diag_thick behaviour the paper's SSVIII.D relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import matern

jax.config.update("jax_enable_x64", True)


def spd(seed, n, decay=0.5):
    """SPD matrix with geometrically decaying off-diagonal mass — the
    covariance-like structure (post-ordering) Algorithm 1 assumes."""
    r = np.random.default_rng(seed)
    idx = np.arange(n)
    base = decay ** (np.abs(idx[:, None] - idx[None, :]) / 8.0)
    noise = 0.01 * r.standard_normal((n, n))
    a = base + noise @ noise.T
    return jnp.asarray(a + n * 0.01 * np.eye(n))


def matern_cov(seed, n, theta=(1.0, 0.1, 0.5), nu=0.5):
    r = np.random.default_rng(seed)
    x = np.sort(r.random((n, 2)), axis=0)  # crude locality ordering
    return np.asarray(
        matern(jnp.asarray(x), jnp.asarray(x), jnp.asarray(theta), nu=nu)
    ) + 1e-6 * np.eye(n)


def test_dp_cholesky_matches_lapack():
    a = spd(0, 128)
    l = model.dp_cholesky(a, nb=32)
    np.testing.assert_allclose(l, jnp.linalg.cholesky(a), rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("nb", [16, 32, 64])
@pytest.mark.parametrize("diag_thick", [1, 2, 3])
def test_mp_cholesky_reconstructs(nb, diag_thick):
    """||L L^T - A|| stays at f32-level for any band width."""
    a = jnp.asarray(matern_cov(1, 128))
    l = model.mp_cholesky(a, nb=nb, diag_thick=diag_thick)
    err = np.abs(np.asarray(l @ l.T - a)).max()
    assert err < 5e-5, f"nb={nb} t={diag_thick}: err={err}"


def test_mp_cholesky_full_band_equals_dp():
    """diag_thick >= p degenerates to the DP algorithm exactly."""
    a = spd(2, 96)
    mp = model.mp_cholesky(a, nb=32, diag_thick=5)
    dp = model.dp_cholesky(a, nb=32)
    np.testing.assert_array_equal(np.asarray(mp), np.asarray(dp))


def test_mp_band_tiles_are_dp_accurate():
    """Tiles inside the band must carry f64-accurate values even when the
    rest of the matrix runs in f32 (the paper's central accuracy claim)."""
    a = jnp.asarray(matern_cov(3, 128))
    dp = np.asarray(model.dp_cholesky(a, nb=32, ))
    mp = np.asarray(model.mp_cholesky(a, nb=32, diag_thick=2))
    # diagonal tiles: always DP in Algorithm 1 (potrf/syrk chains are f64,
    # but their panel inputs crossed f32 — allow f32-scale, expect better)
    for k in range(4):
        dtile = np.abs(dp[k*32:(k+1)*32, k*32:(k+1)*32] - mp[k*32:(k+1)*32, k*32:(k+1)*32]).max()
        assert dtile < 1e-5, f"diag tile {k} err {dtile}"


def test_mp_error_decreases_with_band():
    """Wider DP band -> closer to the full-DP factor (monotone trend)."""
    a = jnp.asarray(matern_cov(4, 160))
    dp = np.asarray(model.dp_cholesky(a, nb=32))
    errs = []
    for t in (1, 2, 4, 5):
        mp = np.asarray(model.mp_cholesky(a, nb=32, diag_thick=t))
        errs.append(np.abs(mp - dp).max())
    assert errs[-1] == 0.0
    assert errs[0] >= errs[-2] >= errs[-1]


def test_dst_cholesky_is_banded():
    a = jnp.asarray(matern_cov(5, 128))
    l = np.asarray(model.dst_cholesky(a, nb=32, diag_thick=2))
    # tiles at |i-j| >= 2 must be exactly zero (the IND/DST structure)
    assert np.all(l[64:128, 0:32] == 0.0)
    assert np.all(l[96:128, 0:64:][:, 0:32] == 0.0)


def test_loglik_matches_direct_inverse():
    n = 96
    a = jnp.asarray(matern_cov(6, n))
    z = jnp.asarray(np.random.default_rng(7).standard_normal(n))
    got = float(model.loglik(a, z))
    an = np.asarray(a)
    want = (
        -0.5 * n * np.log(2 * np.pi)
        - 0.5 * np.linalg.slogdet(an)[1]
        - 0.5 * float(z @ np.linalg.solve(an, np.asarray(z)))
    )
    np.testing.assert_allclose(got, want, rtol=1e-9)


def test_mp_loglik_close_to_dense_loglik():
    """The fused demo graph (matern -> Algorithm 1 -> loglik) agrees with
    the dense-f64 likelihood to f32-resolution — the end-to-end accuracy
    statement of the paper at build time."""
    n, nb = 128, 32
    r = np.random.default_rng(8)
    locs = np.sort(r.random((n, 2)), axis=0)
    theta = jnp.asarray([1.0, 0.1, 0.5])
    sigma = np.asarray(
        matern(jnp.asarray(locs), jnp.asarray(locs), theta, nu=0.5)
    ) + 1e-4 * np.eye(n)
    z = np.linalg.cholesky(sigma) @ r.standard_normal(n)

    dense = float(model.loglik(jnp.asarray(sigma), jnp.asarray(z)))

    # tiled mixed-precision version of the same quantity
    lmp = model.mp_cholesky(jnp.asarray(sigma), nb=nb, diag_thick=2)
    logdet = 2.0 * float(jnp.sum(jnp.log(jnp.diag(lmp))))
    u = jax.scipy.linalg.solve_triangular(lmp, jnp.asarray(z), lower=True)
    mp = -0.5 * n * np.log(2 * np.pi) - 0.5 * logdet - 0.5 * float(u @ u)

    assert abs(mp - dense) / abs(dense) < 1e-4, (mp, dense)

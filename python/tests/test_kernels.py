"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/seeds (the sizes stay small — interpret
mode is numpy-backed); exact tolerances scale with dtype epsilon.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import gemm, matern, potrf, syrk, trsm
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

DTYPES = [jnp.float32, jnp.float64]
SIZES = [8, 16, 64]


def rng_tile(seed, shape, dtype):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.standard_normal(shape), dtype=dtype)


def spd_tile(seed, n, dtype, jitter=None):
    a = np.random.default_rng(seed).standard_normal((n, n))
    s = a @ a.T + (jitter if jitter is not None else n) * np.eye(n)
    return jnp.asarray(s, dtype=dtype)


def tol(dtype):
    return {"float32": 2e-4, "float64": 1e-11}[jnp.dtype(dtype).name]


# ---------------------------------------------------------------- gemm


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.sampled_from(SIZES),
    n=st.sampled_from(SIZES),
    k=st.sampled_from(SIZES),
    dt=st.sampled_from([0, 1]),
    block=st.sampled_from([8, 32, 64]),
)
def test_gemm_matches_ref(seed, m, n, k, dt, block):
    dtype = DTYPES[dt]
    c = rng_tile(seed, (m, n), dtype)
    a = rng_tile(seed + 1, (m, k), dtype)
    b = rng_tile(seed + 2, (n, k), dtype)
    got = gemm(c, a, b, block=block)
    np.testing.assert_allclose(
        got, ref.gemm_ref(c, a, b), rtol=tol(dtype) * k, atol=tol(dtype) * k
    )


def test_gemm_bf16_accumulates_f32():
    c = rng_tile(0, (32, 32), jnp.bfloat16)
    a = rng_tile(1, (32, 32), jnp.bfloat16)
    b = rng_tile(2, (32, 32), jnp.bfloat16)
    got = gemm(c, a, b)
    want = (
        c.astype(jnp.float32)
        - a.astype(jnp.float32) @ b.astype(jnp.float32).T
    ).astype(jnp.bfloat16)
    np.testing.assert_allclose(
        got.astype(np.float32), want.astype(np.float32), rtol=0.05, atol=0.5
    )


def test_gemm_zero_update_is_identity():
    c = rng_tile(3, (16, 16), jnp.float64)
    z = jnp.zeros((16, 8), jnp.float64)
    np.testing.assert_array_equal(gemm(c, z, rng_tile(4, (16, 8), jnp.float64)), c)


# ---------------------------------------------------------------- syrk


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.sampled_from(SIZES),
    k=st.sampled_from(SIZES),
    dt=st.sampled_from([0, 1]),
)
def test_syrk_matches_ref(seed, n, k, dt):
    dtype = DTYPES[dt]
    c = rng_tile(seed, (n, n), dtype)
    a = rng_tile(seed + 1, (n, k), dtype)
    got = syrk(c, a)
    np.testing.assert_allclose(
        got, ref.syrk_ref(c, a), rtol=tol(dtype) * k, atol=tol(dtype) * k
    )


def test_syrk_preserves_symmetry():
    c0 = rng_tile(7, (32, 32), jnp.float64)
    c = c0 + c0.T
    a = rng_tile(8, (32, 16), jnp.float64)
    out = syrk(c, a)
    np.testing.assert_allclose(out, out.T, atol=1e-12)


# ---------------------------------------------------------------- trsm


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.sampled_from(SIZES),
    nb=st.sampled_from([8, 16, 32]),
    dt=st.sampled_from([0, 1]),
)
def test_trsm_matches_ref(seed, m, nb, dt):
    dtype = DTYPES[dt]
    l = jnp.asarray(np.linalg.cholesky(np.asarray(spd_tile(seed, nb, jnp.float64))), dtype)
    b = rng_tile(seed + 1, (m, nb), dtype)
    got = trsm(l, b)
    np.testing.assert_allclose(
        got, ref.trsm_ref(l, b), rtol=tol(dtype) * nb, atol=tol(dtype) * nb
    )


def test_trsm_inverts_gemm():
    """(B L^{-T}) L^T == B — solve then multiply round-trips."""
    l = jnp.asarray(np.linalg.cholesky(np.asarray(spd_tile(5, 16, jnp.float64))))
    b = rng_tile(6, (32, 16), jnp.float64)
    x = trsm(l, b)
    np.testing.assert_allclose(x @ l.T, b, rtol=1e-10, atol=1e-10)


# ---------------------------------------------------------------- potrf


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([4, 8, 16, 32, 64]))
def test_potrf_matches_ref(seed, n):
    a = spd_tile(seed, n, jnp.float64)
    got = potrf(a)
    np.testing.assert_allclose(got, ref.potrf_ref(a), rtol=1e-10, atol=1e-10)


def test_potrf_f32():
    a = spd_tile(11, 16, jnp.float32)
    got = potrf(a)
    np.testing.assert_allclose(got, ref.potrf_ref(a), rtol=1e-3, atol=1e-3)


def test_potrf_strict_upper_zero():
    a = spd_tile(12, 24, jnp.float64)
    got = np.asarray(potrf(a))
    assert np.all(got[np.triu_indices(24, k=1)] == 0.0)


def test_potrf_reconstructs():
    a = spd_tile(13, 32, jnp.float64)
    l = potrf(a)
    np.testing.assert_allclose(l @ l.T, a, rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------- matern


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.sampled_from([8, 16, 64]),
    n=st.sampled_from([8, 16, 64]),
    nu=st.sampled_from([0.5, 1.5, 2.5]),
    var=st.floats(0.1, 10.0),
    rng_=st.floats(0.02, 0.4),
)
def test_matern_matches_ref(seed, m, n, nu, var, rng_):
    r = np.random.default_rng(seed)
    x1 = jnp.asarray(r.random((m, 2)))
    x2 = jnp.asarray(r.random((n, 2)))
    theta = jnp.asarray([var, rng_, nu])
    got = matern(x1, x2, theta, nu=nu)
    np.testing.assert_allclose(
        got, ref.matern_ref(x1, x2, theta, nu), rtol=1e-12, atol=1e-12
    )


@pytest.mark.parametrize("nu", [0.5, 1.5, 2.5])
def test_matern_halfint_agrees_with_general_bessel(nu):
    """The closed forms must equal the general Bessel-K Matern at
    half-integer nu — this pins the Pallas kernel to Eq. 1 itself."""
    r = np.random.default_rng(42)
    x1 = jnp.asarray(r.random((16, 2)))
    theta = jnp.asarray([1.5, 0.1, nu])
    got = matern(x1, x1, theta, nu=nu)
    want = ref.matern_general_ref(x1, x1, theta)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-9, atol=1e-9)


def test_matern_diagonal_is_variance():
    r = np.random.default_rng(3)
    x = jnp.asarray(r.random((32, 2)))
    got = np.asarray(matern(x, x, jnp.asarray([2.5, 0.1, 0.5]), nu=0.5))
    np.testing.assert_allclose(np.diag(got), 2.5)


def test_matern_spd_after_nugget():
    """Sigma from distinct sites is SPD (up to fp) — the property the
    whole pipeline rests on."""
    r = np.random.default_rng(4)
    x = jnp.asarray(r.random((64, 2)))
    s = np.asarray(matern(x, x, jnp.asarray([1.0, 0.1, 1.5]), nu=1.5))
    w = np.linalg.eigvalsh(s)
    assert w.min() > -1e-8 * w.max()
